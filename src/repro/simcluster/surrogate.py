"""Batched fluid surrogate of the event engine (``lax.scan`` × ``vmap``).

The event simulator (``repro.simcluster.sim``) prices every heartbeat,
launch and finish as a discrete event — exact, but one Python process per
cell.  This module trades task-level exactness for three orders of
magnitude in throughput: each cell (trace × policy × seed) becomes a
fixed-timestep **fluid** model whose state is arrays over jobs — pending
map/reduce task mass, slot allocations, locality fractions, latch state —
advanced with ``lax.scan`` over time and ``jax.vmap`` over cells, so
thousands of cells integrate in one XLA computation.

What is modeled (the mesoscale):

* slot capacity (``num_nodes × base_map_slots`` map, same for reduce) and
  per-step allocation by policy ordering — EDF (static deadline priority),
  FIFO (static submission priority), fair deficit (equal-share
  waterfilling);
* the map→reduce phase barrier (reduces only after the job's map mass
  drains, as Algorithm 2 line 10);
* data locality as a hit probability: a free slot finds a local block with
  ``1 − (1 − c/N)^p`` for ``p`` pending tasks whose blocks each live on
  ``c`` distinct nodes of ``N`` — wide backlogs run local, job tails go
  remote, which is the entire economics of delay scheduling and parking;
* the paper's parking mechanism (``park: fixed``) as a conversion of the
  non-local flow into local launches that pay a reconfiguration wait
  instead of the remote-read penalty;
* delay scheduling (``locality_delay``) as an exponent boost on the
  locality hit probability;
* the latching overload detector (``overload: latch``): when the queued
  map backlog and the active-job crowd cross the ``AdaptiveConfig`` entry
  bars, ordering degenerates to fair and parking suspends until the
  cluster drains.

What is **not** modeled — and raises ``SurrogateUnsupported`` instead of
silently answering wrong: the pressure-adaptive park gates (``park:
adaptive`` — donor-interval EWMAs, fail streaks, win-rate floors) and the
reduce-aware latch (``overload: reduce_aware``).  Those live on event-level
signals (per-machine donor timing) with no fluid equivalent; the policies
``adaptive`` and ``adaptive_ra`` stay oracle-only.

Determinism contract (pinned by ``tests/test_surrogate.py``): per
(config, seed) the result is byte-stable on CPU; a batch of one through
``vmap`` is bit-identical to the unbatched kernel; and a cell's result is
invariant to the batch it rides in — padding buckets (``_bucket``) are a
function of the cell alone, never of its batch mates.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policies import PolicySpec
from repro.core.types import AdaptiveConfig, ClusterSpec
from repro.simcluster.traces import Trace, _stable_seed

#: engine identity stamped into cache descriptors and bench entries.  The
#: event engine's cells carry no ``engine`` key at all, so every surrogate
#: hash lands in a disjoint namespace (see tests/test_experiments.py).
SURROGATE_ENGINE_ID = "simcluster.surrogate/fluid-v1"

#: component vocabulary the lowering can express.  Everything else is
#: oracle-only and raises ``SurrogateUnsupported``.
SUPPORTED_COMPONENTS: Dict[str, Tuple[str, ...]] = {
    "ordering": ("edf", "fair_deficit", "fifo"),
    "park": ("off", "fixed"),
    "overload": ("none", "latch"),
}

_ORDERING_CODES = {"edf": 0, "fifo": 1, "fair_deficit": 2}

# -- fluid-model calibration constants ---------------------------------------
# Fitted against paired event-engine cells on the regime atlas (the
# differential wall in tests/test_surrogate.py re-checks the fit on every
# run); they are physics of the mesoscale model, not per-preset knobs.
#: integrator step, seconds of simulated time (2× the heartbeat interval:
#: fine enough that a 20 s map task spans >3 steps, coarse enough that a
#: 3600 s trace is ~600 steps)
DT = 6.0
#: fraction of parked (non-local) map candidates whose reconfiguration
#: resolves locally before the patience bound expires, on an uncrowded
#: cluster; crowding degrades it (see the crowd coupling below)
PARK_SUCCESS = 1.0
#: mean extra seconds a successfully parked map waits for its donor core
#: on an uncrowded cluster (hotplug latency + offer queueing)
PARK_WAIT = 6.0
# crowd coupling — the mesoscale form of the event engine's measured
# park economics: with many active jobs per machine, per-job shares sit
# far below job widths, donor offers queue behind stale ones, waits
# stretch toward the 30 s patience and expired parks still pay the
# remote read afterwards.  χ = clip(active_jobs / machines, 0, 1):
#: park win probability shrinks as (1 − slope × χ)
PARK_CROWD_PENALTY = 1.0
#: successful-park wait grows to PARK_WAIT × (1 + slope × χ)
PARK_WAIT_CROWD = 0.5
#: above χ ≈ 0.6 the donor pool is exhausted and expired parks re-park
#: (depth 2) before finally reading remote: the patience bound stretches
#: by up to this factor at full saturation — the regime that separates
#: synchronized-burst traces (which spike to χ = 1) from steady backlogs
REPARK_CROWD = 6.0
#: saturation ramp for the repark stretch: saturate = clip((χ_raw − SAT_LO)
#: / SAT_WIDTH, 0, 1) on the *uncapped* active/machines ratio, so only
#: backlogs that outrun the fleet (χ_raw → 1+) pay the full stretch
SAT_LO = 0.75
SAT_WIDTH = 0.3
#: effective placement draws per launch for the non-delay schedulers —
#: the event engine's offer scan finds a local-feasible task ~this many
#: times more often than a single uniform draw would (fair and fifo both
#: measure ~0.2 locality against a 1/machines ~ 0.05 uniform baseline)
LOCALITY_DRAWS = 8.0
#: delay scheduling: extra locality draws per skipped offer (multiplies
#: the hit-probability exponent by 1 + boost × locality_delay)
DELAY_BOOST = 0.35
#: delay scheduling's price: a task that gives up and goes remote first
#: sat out its full skip budget — its launch pays an extra
#: ``locality_delay × DELAY_REMOTE_WAIT`` seconds of ring lag
DELAY_REMOTE_WAIT = 2.0
#: fabric contention: remote map reads this step slow each other down by
#: 1 + slope × (remote launch mass / map slots) — a priority wave that
#: sends most of the queue remote at once pays more per read than fair's
#: trickle of the same total remote mass
NET_CONTENTION = 1.25
#: mean task-duration inflation from the straggler process net of
#: speculative re-execution (p × (factor−1), roughly halved by speculation)
TAIL_INFLATION = 1.04
#: waterfilling iterations for the fair-share allocator (exact once the
#: distinct binding demand levels are below this; J ≤ 64 needs few)
_FAIR_ITERS = 8
#: in-flight ring depth, steps: launched tasks occupy their slots for
#: their quantized service time via a (jobs × _RING) delay ring; service
#: lags clip to _RING − 1 (= 378 s at DT, far above any per-task time)
_RING = 64
_EPS = 1e-6
_INF = np.float32(3.0e9)


class SurrogateUnsupported(ValueError):
    """A policy contains a component the fluid surrogate cannot model.

    Carries the offending axis/value so callers can report *why* a policy
    is oracle-only rather than silently approximating it."""

    def __init__(self, label: str, axis: str, value: str):
        self.label = label
        self.axis = axis
        self.value = value
        super().__init__(
            f"policy {label!r} is oracle-only: component {axis}={value!r} "
            f"has no surrogate transition (supported: "
            f"{SUPPORTED_COMPONENTS.get(axis, ())})")


@dataclass(frozen=True)
class LoweredPolicy:
    """A ``PolicySpec`` compiled to the surrogate's scalar program."""

    ordering: int          # _ORDERING_CODES
    park: int              # 0 = off, 1 = fixed
    overload: int          # 0 = none, 1 = latch
    locality_delay: float  # delay-scheduling offers (fair-family only)
    max_wait: float        # park patience bound, seconds (park policies)


def lower_policy(policy) -> LoweredPolicy:
    """Lower a policy value (spec / name / dict / JSON) to the surrogate
    program, or raise :class:`SurrogateUnsupported` — never a silent
    approximation of an unmodeled component."""
    spec = PolicySpec.parse(policy)
    comps = spec.components
    for axis in ("ordering", "park", "overload"):
        value = comps.get(axis)
        if value not in SUPPORTED_COMPONENTS[axis]:
            raise SurrogateUnsupported(spec.label, axis, str(value))
    params = spec.effective_params()
    park = 1 if comps["park"] == "fixed" else 0
    return LoweredPolicy(
        ordering=_ORDERING_CODES[comps["ordering"]],
        park=park,
        overload=1 if comps["overload"] == "latch" else 0,
        locality_delay=float(params.get("locality_delay", 0) or 0),
        max_wait=float(params.get("max_wait", 30.0)) if park else 0.0)


def surrogate_supported(policy) -> bool:
    """True when :func:`lower_policy` would accept this policy."""
    try:
        lower_policy(policy)
        return True
    except SurrogateUnsupported:
        return False


# ---------------------------------------------------------------------------
# cell construction (host side, numpy)
# ---------------------------------------------------------------------------

def _bucket(n: int, base: int) -> int:
    """Smallest ``base × 2^k`` ≥ n — a deterministic function of the cell
    alone, so padded shapes (and therefore results) cannot depend on what
    else shares the batch."""
    size = base
    while size < n:
        size *= 2
    return size


@dataclass
class SurrogateCellInputs:
    """One cell's arrays, unpadded (jobs axis = J), plus static scalars."""

    # per-job arrays, float32/np
    submit: np.ndarray          # absolute submit time
    dl_abs: np.ndarray          # absolute deadline
    u_m: np.ndarray             # map tasks
    v_r: np.ndarray             # reduce tasks
    map_t: np.ndarray           # mean local map-task seconds (jittered)
    red_t: np.ndarray           # mean reduce-task seconds (jittered)
    c_repl: np.ndarray          # mean distinct replica nodes per map block
    # cell scalars
    n_nodes: int
    n_machines: int
    map_slots: float
    red_slots: float
    remote_mult: float          # remote map duration multiplier
    policy: LoweredPolicy
    # latch entry bars (AdaptiveConfig defaults unless the cluster overrides)
    overload_pending_factor: float
    overload_active_factor: float
    horizon: float
    job_ids: List[str]
    workloads: List[str]
    input_gb: List[float]
    deadlines_rel: np.ndarray

    @property
    def n_jobs(self) -> int:
        return int(self.submit.shape[0])

    def padded_jobs(self) -> int:
        return _bucket(self.n_jobs, 8)

    def n_steps(self) -> int:
        return _bucket(int(math.ceil(self.horizon / DT)), 256)


def build_cell(trace: Trace, cluster: ClusterSpec, policy,
               seed: int) -> SurrogateCellInputs:
    """Compile one (trace, cluster, policy) cell to surrogate inputs.

    Uses the *actual* trace jobs — submit times, task counts, profiles,
    deadlines and the per-seed block placements — so the surrogate shares
    every input the event engine sees and approximates only the dynamics.
    ``seed`` additionally drives a small per-job duration jitter standing
    in for the event engine's per-task lognormal draw."""
    lowered = lower_policy(policy)
    jobs = trace.job_specs(cluster)
    n = len(jobs)
    if n == 0:
        raise ValueError("surrogate cell needs at least one job")
    rng = np.random.default_rng(
        _stable_seed("surrogate-jitter", trace.name, trace.seed, seed))
    submit = np.array([j.submit_time for j in jobs], np.float32)
    dl_rel = np.array([j.deadline for j in jobs], np.float32)
    u_m = np.array([j.u_m for j in jobs], np.float32)
    v_r = np.array([j.v_r for j in jobs], np.float32)
    # per-job mean durations; the phase mean over u_m iid task draws
    # concentrates ∝ 1/sqrt(u_m), which the jitter std reproduces
    map_t = np.empty(n, np.float32)
    red_t = np.empty(n, np.float32)
    c_repl = np.empty(n, np.float32)
    for i, j in enumerate(jobs):
        prof = j.profile
        cv = getattr(prof, "time_cv", 0.08)
        z_m, z_r = rng.standard_normal(2)
        jitter_m = math.exp(cv * z_m / math.sqrt(max(j.u_m, 1)))
        jitter_r = math.exp(cv * z_r / math.sqrt(max(j.v_r, 1)))
        map_t[i] = prof.map_time * TAIL_INFLATION * jitter_m
        red_t[i] = ((prof.reduce_time + j.u_m * prof.shuffle_time_per_pair)
                    * TAIL_INFLATION * jitter_r)
        if j.block_placement:
            c_repl[i] = float(np.mean(
                [len(set(p)) for p in j.block_placement[:j.u_m]]))
        else:
            c_repl[i] = float(min(cluster.replication, cluster.num_nodes))
    # remote penalty is profile-uniform today (1.0); keep the first job's
    # profile as the cell's fabric calibration like the event engine does
    rp = jobs[0].profile.remote_penalty
    remote_mult = 1.0 + rp * cluster.remote_penalty_scale
    map_slots = float(cluster.num_nodes * cluster.base_map_slots)
    red_slots = float(cluster.num_nodes * cluster.base_reduce_slots)
    total_work = (float(np.sum(u_m * map_t)) * remote_mult / map_slots
                  + float(np.sum(v_r * red_t)) / red_slots)
    horizon = float(np.max(submit)) + 3.0 * total_work + 900.0
    adaptive = cluster.adaptive if isinstance(cluster.adaptive,
                                              AdaptiveConfig) else AdaptiveConfig()
    return SurrogateCellInputs(
        submit=submit, dl_abs=submit + dl_rel, u_m=u_m, v_r=v_r,
        map_t=map_t, red_t=red_t, c_repl=c_repl,
        n_nodes=cluster.num_nodes, n_machines=cluster.num_machines,
        map_slots=map_slots, red_slots=red_slots, remote_mult=remote_mult,
        policy=lowered,
        overload_pending_factor=adaptive.overload_pending_factor,
        overload_active_factor=adaptive.overload_active_factor,
        horizon=horizon,
        job_ids=[j.job_id for j in jobs],
        workloads=[j.profile.name for j in jobs],
        input_gb=[j.input_size_gb for j in jobs],
        deadlines_rel=dl_rel)


# ---------------------------------------------------------------------------
# the kernel: lax.scan over time, vmap over cells
# ---------------------------------------------------------------------------

#: names and order of the per-job tensor rows handed to the kernel
_JOB_FIELDS = ("submit", "dl_abs", "map_mass0", "red_mass0", "lag_ml",
               "lag_mr", "lag_rr", "c_over_n", "prio_key", "pad_mask")
#: per-cell scalar rows
_SCALAR_FIELDS = ("map_slots", "red_slots", "machines", "remote_mult",
                  "ordering", "park", "overload", "locality_delay",
                  "max_wait", "pending_bar", "active_bar")


def pack_cell(cell: SurrogateCellInputs) -> Dict[str, np.ndarray]:
    """Pad one cell's arrays to its job bucket and stack the kernel inputs.
    Padding jobs carry zero mass and a pad mask of 0 — they can never
    activate, allocate, or finish."""
    jp = cell.padded_jobs()
    n = cell.n_jobs

    def pad(a: np.ndarray, fill: float = 0.0) -> np.ndarray:
        out = np.full(jp, fill, np.float32)
        out[:n] = a.astype(np.float32)
        return out

    pol = cell.policy
    # priority key: FIFO sorts by submission, EDF by absolute deadline;
    # fair ignores the key entirely.  jnp.argsort is stable, so ties
    # resolve by job index — the event schedulers' admission-seq tiebreak.
    if pol.ordering == _ORDERING_CODES["fifo"]:
        prio = cell.submit.copy()
    else:
        prio = cell.dl_abs.copy()
    def lag(seconds: np.ndarray) -> np.ndarray:
        return np.clip(np.round(seconds / DT), 1, _RING - 1)

    jobs = {
        "submit": pad(cell.submit, fill=_INF),
        "dl_abs": pad(cell.dl_abs, fill=_INF),
        "map_mass0": pad(cell.u_m),
        "red_mass0": pad(cell.v_r),
        "lag_ml": pad(lag(cell.map_t), fill=1.0),
        "lag_mr": pad(lag(cell.map_t * cell.remote_mult), fill=1.0),
        "lag_rr": pad(lag(cell.red_t), fill=1.0),
        "c_over_n": pad(np.minimum(cell.c_repl / cell.n_nodes, 0.999)),
        "prio_key": pad(prio, fill=_INF),
        "pad_mask": pad(np.ones(n, np.float32)),
    }
    scalars = {
        "map_slots": cell.map_slots,
        "red_slots": cell.red_slots,
        "machines": float(cell.n_machines),
        "remote_mult": cell.remote_mult,
        "ordering": float(pol.ordering),
        "park": float(pol.park),
        "overload": float(pol.overload),
        "locality_delay": pol.locality_delay,
        "max_wait": pol.max_wait,
        "pending_bar": cell.overload_pending_factor * cell.map_slots,
        "active_bar": cell.overload_active_factor * cell.n_machines,
    }
    packed = {k: jobs[k] for k in _JOB_FIELDS}
    packed.update({k: np.float32(scalars[k]) for k in _SCALAR_FIELDS})
    return packed


def _fair_waterfill(jnp, demand, capacity):
    """Equal-share progressive filling of ``capacity`` over ``demand``
    (deficit round-robin's fluid limit).  Unrolled fixed-point: each round
    splits the leftover equally among unsatisfied jobs."""
    alloc = jnp.zeros_like(demand)
    for _ in range(_FAIR_ITERS):
        need = demand - alloc
        unsat = (need > _EPS).astype(demand.dtype)
        n_unsat = jnp.maximum(jnp.sum(unsat), 1.0)
        leftover = jnp.maximum(capacity - jnp.sum(alloc), 0.0)
        share = leftover / n_unsat
        alloc = alloc + jnp.minimum(need, share) * unsat
    return alloc


def _priority_alloc(jnp, demand, capacity, order, inv_order):
    """Strict-priority waterfilling: jobs take their full demand in
    ``order`` until capacity runs out.  ``order``/``inv_order`` are the
    static priority permutation and its inverse."""
    d_sorted = jnp.take(demand, order)
    before = jnp.cumsum(d_sorted) - d_sorted
    a_sorted = jnp.clip(capacity - before, 0.0, d_sorted)
    return jnp.take(a_sorted, inv_order)


def _make_kernel(n_jobs: int, n_steps: int, diag: bool = False):
    """Build the single-cell scan kernel for a (jobs, steps) bucket.

    The dynamics are a *discrete-lag fluid*: pending task mass launches
    into free slots and sits in a (jobs × ``_RING``) in-flight delay ring
    for its quantized service time before completing — so waves, slot
    occupancy, queueing and the map→reduce barrier are all emergent, with
    no closed-form drain law to mis-calibrate.  A launch's service lag is
    its locality outcome (local / remote / parked), so locality economics
    feed straight into capacity.

    Returns ``kernel(packed) -> outputs`` where outputs are per-job
    ``finish`` times (``_INF`` = unfinished), accumulated local/remote
    launch mass, and the latched-step count.  ``diag=True`` additionally
    stacks per-step cluster aggregates (active jobs, queued mass, free
    slots, launch totals, launch-weighted locality, crowding, latch) —
    the observability hook calibration probes use.  Pure jnp: safe under
    both direct call and ``vmap``."""
    import jax
    import jax.numpy as jnp

    dt = np.float32(DT)
    L = _RING

    def kernel(p):
        order = jnp.argsort(p["prio_key"])
        inv_order = jnp.argsort(order)
        submit = p["submit"]
        pad_mask = p["pad_mask"]
        lag_ml = p["lag_ml"].astype(jnp.int32)
        lag_mr = p["lag_mr"].astype(jnp.int32)
        lag_rr = p["lag_rr"].astype(jnp.int32)
        log_miss = jnp.log1p(-p["c_over_n"])       # per-job, < 0
        use_fair_ordering = p["ordering"] >= 1.5   # fair_deficit code
        # delay scheduling: each skipped offer is more locality draws
        ell_exponent = 1.0 + DELAY_BOOST * p["locality_delay"]

        def step(carry, it):
            (pend_m, ring_m, pend_r, ring_r, park_s, park_x, finish,
             loc_acc, rem_acc, latch, lsteps) = carry
            t = it.astype(jnp.float32) * dt
            submitted = (submit <= t).astype(jnp.float32) * pad_mask
            # completions leave the ring first — they free slots this
            # step.  Ring maintenance is O(J) scatter/gather on the
            # maturing column; each ring pays exactly one full O(J·L)
            # reduction per step and every later sum is derived from it
            # arithmetically (the scan spends its time in these rows).
            idx = jnp.mod(it, L)
            ring_m = ring_m.at[:, idx].set(0.0)
            ring_r = ring_r.at[:, idx].set(0.0)
            # parked mass whose wait matures this step enters service: a
            # successful park runs local, an expired one reads remote
            mat_s = park_s[:, idx]
            mat_x = park_x[:, idx]
            park_s = park_s.at[:, idx].set(0.0)
            park_x = park_x.at[:, idx].set(0.0)
            inflight_m = jnp.sum(ring_m, axis=1)
            inflight_r = jnp.sum(ring_r, axis=1)
            waiting = jnp.sum(park_s, axis=1) + jnp.sum(park_x, axis=1)
            map_left = pend_m + inflight_m + waiting + mat_s + mat_x
            red_left = pend_r + inflight_r
            map_open = submitted * (map_left > _EPS)
            red_open = submitted * (map_left <= _EPS) * (red_left > _EPS)
            # latch entry/exit on beginning-of-step queue pressure
            pending = jnp.sum(pend_m * submitted)
            active = jnp.sum(submitted * ((map_left > _EPS)
                                          | (red_left > _EPS)))
            trip = ((pending >= p["pending_bar"])
                    & (active >= p["active_bar"]))
            latch = (p["overload"] > 0.5) & ((latch | trip) & (active > 0.5))
            use_fair = use_fair_ordering | latch
            park_on = (p["park"] > 0.5) & ~latch
            chi_raw = active / p["machines"]
            chi = jnp.clip(chi_raw, 0.0, 1.0)
            # -- map demand ----------------------------------------------
            # a parked task donates its core to the reconfiguration pool,
            # where it is *held* for the donor wait — unavailable to the
            # scheduler.  That capacity holdback is the park tax the
            # oracle measures (diurnal proposed runs the map pool at
            # ~50% utilization through its overload phase).
            free_m = jnp.maximum(
                p["map_slots"] - jnp.sum(inflight_m) - jnp.sum(waiting),
                0.0)
            # two allocation rounds, after the event scheduler's
            # demand/backfill phases: round 1 caps each job at its share
            # of the pool (parked tasks count as in-flight against it),
            # round 2 backfills leftover slots with any uncapped pending
            # mass — so a heavy-tailed giant keeps freed slots busy,
            # while a fleet of similar jobs that all parked together has
            # nothing left to backfill with and the pool idles.
            n_open = jnp.maximum(jnp.sum(map_open), 1.0)
            share = p["map_slots"] / n_open
            cap = jnp.maximum(share - waiting, 0.0)
            offered = jnp.minimum(pend_m, cap) * map_open
            launch1 = jnp.where(
                use_fair,
                _fair_waterfill(jnp, offered, free_m),
                _priority_alloc(jnp, offered, free_m, order, inv_order))
            spare = jnp.maximum(free_m - jnp.sum(launch1), 0.0)
            off2 = jnp.maximum(pend_m - launch1, 0.0) * map_open
            launch2 = jnp.where(
                use_fair,
                _fair_waterfill(jnp, off2, spare),
                _priority_alloc(jnp, off2, spare, order, inv_order))
            launch = launch1 + launch2
            blocked = jnp.sum(waiting)
            # baseline locality: the offer scan's effective placement
            # draws per launch (constant — the event engine books ~the
            # same locality for fair and fifo); delay scheduling's skipped
            # offers multiply the draws
            lf_base = 1.0 - jnp.exp(ell_exponent * LOCALITY_DRAWS
                                    * log_miss)
            launch_loc = launch * lf_base
            rest = launch - launch_loc
            # park outcome odds and waits, degraded by the active crowd
            # (donor cores are co-located VMs' spare capacity)
            wait_eff = jnp.minimum(
                PARK_WAIT * (1.0 + PARK_WAIT_CROWD * chi), p["max_wait"])
            p_succ = PARK_SUCCESS * jnp.maximum(
                1.0 - PARK_CROWD_PENALTY * chi, 0.0)
            ws = jnp.round(wait_eff / dt).astype(jnp.int32)
            saturate = jnp.clip((chi_raw - SAT_LO) / SAT_WIDTH, 0.0, 1.0)
            wx = jnp.minimum(jnp.round(
                p["max_wait"] * (1.0 + REPARK_CROWD * saturate) / dt
            ).astype(jnp.int32), L - 1)
            # deadline-critical bypass (the event reconfigurator's own
            # guard, verbatim): a job inside 3x the park patience of its
            # absolute deadline skips parking and reads remote
            # immediately — so a blown-deadline backlog stops donating
            # its launches to the park queue.
            crit = (p["dl_abs"] - t) <= 3.0 * p["max_wait"]
            park_f = park_on.astype(jnp.float32) \
                * (1.0 - crit.astype(jnp.float32))
            f_psucc = rest * park_f * p_succ
            f_pexp = rest * park_f * (1.0 - p_succ)
            f_rem = rest * (1.0 - park_f)
            # remote reads launched together contend on the fabric
            rem_load = jnp.sum(f_rem + mat_x) / p["map_slots"]
            delay_lag = jnp.round(
                DELAY_REMOTE_WAIT * p["locality_delay"] / dt
            ).astype(jnp.int32)
            lag_mr_eff = jnp.minimum(
                lag_mr + delay_lag + jnp.round(
                    lag_mr.astype(jnp.float32) * NET_CONTENTION * rem_load
                ).astype(jnp.int32), L - 1)
            rows = jnp.arange(n_jobs)
            ring_m = ring_m.at[rows, jnp.mod(it + lag_ml, L)].add(
                launch_loc + mat_s)
            ring_m = ring_m.at[rows, jnp.mod(it + lag_mr_eff, L)].add(
                f_rem + mat_x)
            park_s = park_s.at[:, jnp.mod(it + ws, L)].add(f_psucc)
            park_x = park_x.at[:, jnp.mod(it + wx, L)].add(f_pexp)
            pend_m = jnp.maximum(pend_m - launch, 0.0)
            pend_m = jnp.where(pend_m <= 0.01, 0.0, pend_m)
            loc_acc = loc_acc + launch_loc + f_psucc
            rem_acc = rem_acc + f_rem + f_pexp
            lf = (launch_loc + f_psucc) / jnp.maximum(launch, _EPS)
            # -- reduce --------------------------------------------------
            off_r = pend_r * red_open
            free_r = jnp.maximum(p["red_slots"] - jnp.sum(inflight_r), 0.0)
            launch_r = jnp.where(
                use_fair,
                _fair_waterfill(jnp, off_r, free_r),
                _priority_alloc(jnp, off_r, free_r, order, inv_order))
            ring_r = ring_r.at[rows, jnp.mod(it + lag_rr, L)].add(launch_r)
            pend_r = jnp.maximum(pend_r - launch_r, 0.0)
            pend_r = jnp.where(pend_r <= 0.01, 0.0, pend_r)
            # -- completions ---------------------------------------------
            # post-launch remaining mass, derived from the pre-launch
            # reductions plus exactly what this step scattered in
            map_left = pend_m + inflight_m + launch_loc + mat_s \
                + f_rem + mat_x + waiting + f_psucc + f_pexp
            red_left = pend_r + inflight_r + launch_r
            done = (submitted > 0.5) & (map_left <= _EPS) \
                & (red_left <= _EPS)
            finish = jnp.where(done & (finish >= _INF), t + dt, finish)
            lsteps = lsteps + latch.astype(jnp.float32)
            ys = None
            if diag:
                lsum = jnp.maximum(jnp.sum(launch), _EPS)
                ys = {"active": active, "pending": pending,
                      "free_m": free_m, "free_r": free_r,
                      "waiting": jnp.sum(waiting), "blocked": blocked,
                      "launched_m": jnp.sum(launch),
                      "launched_r": jnp.sum(launch_r),
                      "lf": jnp.sum(lf * launch) / lsum,
                      "chi": chi,
                      "latch": latch.astype(jnp.float32)}
            return (pend_m, ring_m, pend_r, ring_r, park_s, park_x,
                    finish, loc_acc, rem_acc, latch, lsteps), ys

        init = (p["map_mass0"],
                jnp.zeros((n_jobs, L), jnp.float32),
                p["red_mass0"],
                jnp.zeros((n_jobs, L), jnp.float32),
                jnp.zeros((n_jobs, L), jnp.float32),
                jnp.zeros((n_jobs, L), jnp.float32),
                jnp.full((n_jobs,), _INF, jnp.float32),
                jnp.zeros((n_jobs,), jnp.float32),
                jnp.zeros((n_jobs,), jnp.float32),
                jnp.asarray(False),
                jnp.asarray(0.0, jnp.float32))
        if diag:
            its = jnp.arange(n_steps, dtype=jnp.int32)
            final, ys = jax.lax.scan(step, init, its)
        else:
            # early exit at chunk granularity: once every real job has
            # finished, further steps are exact no-ops (no pending mass,
            # empty rings, latch released), so skipping them is
            # bit-identical to integrating the full horizon — the scan
            # just stops paying for the drain tail.
            chunk = 256
            n_chunks = max(n_steps // chunk, 1)

            def unfinished(carry):
                return jnp.any((carry[6] >= _INF) & (pad_mask > 0.5))

            def cond(state):
                carry, c = state
                return (c < n_chunks) & unfinished(carry)

            def body(state):
                carry, c = state
                its = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
                carry, _ = jax.lax.scan(step, carry, its)
                return (carry, c + 1)

            final, _ = jax.lax.while_loop(
                cond, body, (init, jnp.asarray(0, jnp.int32)))
            ys = None
        (pend_m, _, pend_r, _, _, _, finish, loc_acc, rem_acc, _,
         lsteps) = final
        out = {"finish": finish, "local": loc_acc, "remote": rem_acc,
               "map_rem": pend_m, "red_rem": pend_r,
               "latched_steps": lsteps}
        if diag:
            out["diag"] = ys
        return out

    return kernel


_KERNEL_CACHE: Dict[Tuple[int, int, bool, bool], object] = {}

#: cells per vmapped sub-batch in run_batch — large enough to amortize
#: dispatch, small enough that the scan carry stays cache-resident.
#: Overridable per-call (``run_batch(..., max_batch=...)``) or process-wide
#: via ``REPRO_SURROGATE_MAX_BATCH``; per-cell results are independent of
#: the sub-batch split, so overrides only move the dispatch/cache tradeoff.
_MAX_BATCH = 64


def _resolve_max_batch(max_batch: Optional[int] = None) -> int:
    """Sub-batch cap for ``run_batch``: explicit kwarg beats the
    ``REPRO_SURROGATE_MAX_BATCH`` env var beats the built-in default."""
    if max_batch is None:
        env = os.environ.get("REPRO_SURROGATE_MAX_BATCH")
        if env:
            max_batch = int(env)
        else:
            return _MAX_BATCH
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    return max_batch


def _compiled(n_jobs: int, n_steps: int, batched: bool, diag: bool = False):
    """jit-compiled kernel per (bucket, batched) — the cache keeps repeat
    sweeps from re-tracing."""
    import jax
    key = (n_jobs, n_steps, batched, diag)
    fn = _KERNEL_CACHE.get(key)
    if fn is None:
        kernel = _make_kernel(n_jobs, n_steps, diag=diag)
        fn = jax.jit(jax.vmap(kernel) if batched else kernel)
        _KERNEL_CACHE[key] = fn
    return fn


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclass
class SurrogateJob:
    job_id: str
    workload: str
    input_gb: float
    submit_time: float
    deadline: float              # relative
    finish_time: Optional[float]
    completion_time: Optional[float]
    deadline_met: bool
    local_map_launches: float
    remote_map_launches: float


@dataclass
class SurrogateResult:
    """Per-cell estimates, mirroring the ``SimResult`` metric surface the
    warehouse consumes (throughput/locality/deadlines)."""

    makespan: float
    jobs_total: int
    jobs_finished: int
    deadlines_met: int
    locality_rate: float
    latched_steps: float
    jobs: List[SurrogateJob]
    # per-step cluster aggregates, present when run with diag=True
    diag: Optional[Dict[str, np.ndarray]] = None

    def throughput_jobs_per_hour(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.jobs_finished * 3600.0 / self.makespan


def _unpack_result(cell: SurrogateCellInputs, out: Dict[str, np.ndarray]
                   ) -> SurrogateResult:
    n = cell.n_jobs
    finish = np.asarray(out["finish"][:n], np.float64)
    local = np.asarray(out["local"][:n], np.float64)
    remote = np.asarray(out["remote"][:n], np.float64)
    latched = float(np.asarray(out["latched_steps"]))
    finished = finish < float(_INF)
    jobs: List[SurrogateJob] = []
    deadlines = 0
    for i in range(n):
        ft = float(finish[i]) if finished[i] else None
        ct = None if ft is None else ft - float(cell.submit[i])
        met = ft is not None and ft <= float(cell.dl_abs[i]) + 1e-6
        deadlines += int(met)
        jobs.append(SurrogateJob(
            job_id=cell.job_ids[i], workload=cell.workloads[i],
            input_gb=float(cell.input_gb[i]),
            submit_time=float(cell.submit[i]),
            deadline=float(cell.deadlines_rel[i]),
            finish_time=ft, completion_time=ct, deadline_met=met,
            local_map_launches=float(local[i]),
            remote_map_launches=float(remote[i])))
    makespan = float(np.max(finish[finished])) if finished.any() \
        else cell.horizon
    launches = float(local.sum() + remote.sum())
    return SurrogateResult(
        makespan=makespan, jobs_total=n,
        jobs_finished=int(finished.sum()), deadlines_met=deadlines,
        locality_rate=float(local.sum()) / launches if launches else 0.0,
        latched_steps=latched, jobs=jobs)


def run_cell(cell: SurrogateCellInputs,
             diag: bool = False) -> SurrogateResult:
    """Integrate one cell through the *unbatched* kernel (the reference
    path the batch determinism tests compare against).  ``diag=True``
    attaches per-step cluster aggregates as ``result.diag`` (dict of
    time-series arrays) for calibration probes."""
    packed = pack_cell(cell)
    out = _compiled(cell.padded_jobs(), cell.n_steps(),
                    batched=False, diag=diag)(packed)
    traj = out.pop("diag", None)
    result = _unpack_result(cell,
                            {k: np.asarray(v) for k, v in out.items()})
    if traj is not None:
        result.diag = {k: np.asarray(v) for k, v in traj.items()}
    return result


def run_batch(cells: Sequence[SurrogateCellInputs], *,
              max_batch: Optional[int] = None) -> List[SurrogateResult]:
    """Integrate many cells, grouped by (jobs, steps) bucket and run
    through ``vmap`` in sub-batches of ``max_batch`` (default ``_MAX_BATCH``,
    overridable via ``REPRO_SURROGATE_MAX_BATCH``) — a handful of XLA
    computations for thousands of cells per call.  Results come back in
    input order and are bit-identical to ``run_cell`` on each cell alone,
    whatever the sub-batch cap (pinned by the fuzz suite)."""
    cap = _resolve_max_batch(max_batch)
    groups: Dict[Tuple[int, int], List[int]] = {}
    for i, cell in enumerate(cells):
        groups.setdefault((cell.padded_jobs(), cell.n_steps()), []).append(i)
    results: List[Optional[SurrogateResult]] = [None] * len(cells)
    for (jp, ts), idxs in groups.items():
        # sub-batch each bucket: per-cell results are independent of batch
        # composition (pinned by the fuzz suite), and moderate batches keep
        # the scan carry cache-resident — a single huge vmap thrashes
        for lo in range(0, len(idxs), cap):
            part = idxs[lo:lo + cap]
            packed = [pack_cell(cells[i]) for i in part]
            stacked = {k: np.stack([q[k] for q in packed])
                       for k in packed[0]}
            out = _compiled(jp, ts, batched=True)(stacked)
            out = {k: np.asarray(v) for k, v in out.items()}
            for row, i in enumerate(part):
                results[i] = _unpack_result(
                    cells[i], {k: v[row] for k, v in out.items()})
    return results  # type: ignore[return-value]
