from repro.analysis.hlo import analyze_hlo, HloSummary
