"""Structural analysis of optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` visits a ``while`` body ONCE —
for scan-over-layers programs it under-counts FLOPs and bytes by ~L×.  This
module parses the optimized HLO, builds the computation call graph with
known trip counts (XLA annotates ``known_trip_count`` on while ops), and
reports *trip-scaled*:

* dot/convolution FLOPs,
* per-collective wire bytes (ring-model per device):
    all-gather      (g-1)/g · out_bytes
    reduce-scatter  (g-1)   · out_bytes          (= (g-1)/g · in_bytes)
    all-reduce      2(g-1)/g · bytes
    all-to-all      (g-1)/g · bytes
    collective-permute  bytes

Used by the dry-run to derive the roofline collective term and to validate
the analytic FLOPs model.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count[\"\s:{]+n[\"\s:]+\"?(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class HloOp:
    name: str
    text: str


@dataclass
class HloComputation:
    name: str
    ops: List[HloOp] = field(default_factory=list)
    # name -> result type string (for operand shape lookup)
    types: Dict[str, str] = field(default_factory=dict)


@dataclass
class HloSummary:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    hbm_bytes: float = 0.0            # trip-scaled materialization traffic
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    unknown_trip_loops: int = 0
    per_collective: List[Dict] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def total_flops(self) -> float:
        return self.dot_flops + self.conv_flops

    def to_json(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "conv_flops": self.conv_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "unknown_trip_loops": self.unknown_trip_loops,
        }


def parse_computations(text: str) -> Tuple[Dict[str, HloComputation], Optional[str]]:
    comps: Dict[str, HloComputation] = {}
    entry = None
    cur: Optional[HloComputation] = None
    for line in text.splitlines():
        m = _COMP_HDR_RE.match(line.strip()) if "{" in line and "->" in line else None
        if m and not line.lstrip().startswith("//"):
            cur = HloComputation(m.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            name, rest = om.group(1), om.group(2)
            cur.ops.append(HloOp(name, rest))
            cur.types[name] = rest
    return comps, entry


def _group_size(text: str, default: int = 1) -> int:
    m = _GROUPS_LIST_RE.search(text)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(text)
    if m:
        return int(m.group(2))
    return default


_DOT_OPERAND_RE = re.compile(
    r"(?:(\w+\[[\d,]*\](?:\{[\d,]*\})?)\s+)?%([\w\.\-]+)")


def _dot_flops(op: HloOp, comp: HloComputation) -> float:
    """2 * prod(out_dims) * prod(contracting dims of lhs)."""
    out = _shape_dims(op.text.split(" dot(")[0])
    if out is None:
        return 0.0
    _, out_dims = out
    m = re.search(r"dot\(([^)]*)\)", op.text)
    if not m:
        return 0.0
    # operands are either bare names ("%a, %b", older dumps) or typed
    # ("f32[128,256]{1,0} %a, ...", newer dumps) — handle both
    operands = _DOT_OPERAND_RE.findall(m.group(1))
    cm = _CONTRACT_RE.search(op.text)
    if not operands or cm is None:
        return 0.0
    lhs_inline_type, lhs_name = operands[0]
    lhs = _shape_dims(lhs_inline_type) if lhs_inline_type else None
    if lhs is None:
        lhs_type = comp.types.get(lhs_name, "")
        lhs = _shape_dims(lhs_type.split("=")[0]
                          if "=" in lhs_type else lhs_type)
    if lhs is None:
        # operand may be a parameter: search type in its defining text anyway
        return 0.0
    _, lhs_dims = lhs
    kprod = 1
    if cm.group(1):
        for d in cm.group(1).split(","):
            kprod *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * kprod


_CONST_RE = re.compile(r"^\s*s\d+\[\]\s+constant\((\d+)\)")
_CMP_LT_RE = re.compile(
    r"compare\([^)]*%([\w\.\-]+)\s*\)\s*,\s*direction=LT")


def _infer_trip_count(cond: Optional[HloComputation]) -> Optional[int]:
    """Bound a counted loop from its condition when XLA omitted
    ``known_trip_count``: a root ``compare(induction, constant), LT`` with a
    0-based unit-step induction variable (what jax.lax.scan lowers to) trips
    exactly ``constant`` times."""
    if cond is None:
        return None
    for op in cond.ops:
        # compound conditions (early-exit loops) are not counted loops
        if " and(" in op.text or " or(" in op.text:
            return None
    for op in cond.ops:
        txt = op.text
        if " compare(" not in txt or "direction=LT" not in txt:
            continue
        m = _CMP_LT_RE.search(txt)
        if not m:
            continue
        bound_op = cond.types.get(m.group(1), "")
        cm = _CONST_RE.match(bound_op)
        if cm:
            return int(cm.group(1))
    return None


def analyze_hlo(text: str) -> HloSummary:
    comps, entry = parse_computations(text)
    s = HloSummary()
    if entry is None:
        return s

    # resolve trip counts once per while op (annotation, else inferred from
    # the loop condition); unknown loops are counted here exactly once
    trips: Dict[int, int] = {}
    for comp in comps.values():
        for op in comp.ops:
            if " while(" not in op.text:
                continue
            tm = _TRIP_RE.search(op.text)
            if tm is not None:
                trips[id(op)] = int(tm.group(1))
                continue
            cm0 = _COND_RE.search(op.text)
            inferred = _infer_trip_count(
                comps.get(cm0.group(1)) if cm0 else None)
            if inferred is None:
                s.unknown_trip_loops += 1
            trips[id(op)] = inferred if inferred is not None else 1

    # multipliers via BFS from entry
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # repeatedly propagate (call graph is a DAG; few passes suffice)
    for _ in range(64):
        changed = False
        for cname, comp in comps.items():
            m0 = mult.get(cname, 0.0)
            if m0 == 0.0:
                continue
            for op in comp.ops:
                if " while(" in op.text:
                    bm = _BODY_RE.search(op.text)
                    trip = trips[id(op)]
                    if bm:
                        tgt = bm.group(1)
                        val = m0 * trip
                        if mult.get(tgt, 0.0) < val:
                            mult[tgt] = val
                            changed = True
                    cm_ = _COND_RE.search(op.text)
                    if cm_:
                        tgt = cm_.group(1)
                        val = m0 * (trip + 1)
                        if mult.get(tgt, 0.0) < val:
                            mult[tgt] = val
                            changed = True
                elif " call(" in op.text or "fusion(" in op.text or "conditional(" in op.text:
                    for tgt in _CALLS_RE.findall(op.text):
                        if mult.get(tgt, 0.0) < m0:
                            mult[tgt] = m0
                            changed = True
        if not changed:
            break

    for cname, comp in comps.items():
        m0 = mult.get(cname, 0.0)
        if m0 == 0.0:
            continue
        for op in comp.ops:
            txt = op.text
            # HBM-traffic model: every materializing top-level op writes its
            # output once and that buffer is read ~once by its consumer, so
            # traffic ≈ 2 × Σ output bytes (fusion internals never hit HBM).
            # Excluded: control/aliasing ops that produce no new buffer.
            mop = re.match(r"\s*(?:\([^=]*\)|\S+)\s+(\w[\w\-]*)\(", txt)
            opname = mop.group(1) if mop else ""
            if opname and opname not in (
                    "parameter", "tuple", "get-tuple-element", "constant",
                    "while", "conditional", "bitcast", "custom-call",
                    "after-all", "partition-id", "replica-id"):
                result_type = txt.split(f" {opname}(")[0]
                s.hbm_bytes += 2.0 * m0 * _shapes_bytes(result_type)
            if " dot(" in txt:
                s.dot_flops += m0 * _dot_flops(op, comp)
            elif " convolution(" in txt:
                # approximate: 2 * out_elems * (window elems * in_ch) unknown
                out = _shape_dims(txt)
                if out:
                    n = 1
                    for d in out[1]:
                        n *= d
                    s.conv_flops += m0 * 2 * n
            for coll in COLLECTIVES:
                token = f" {coll}(" if f" {coll}(" in txt else (
                    f" {coll}-start(" if f" {coll}-start(" in txt else None)
                if token is None:
                    continue
                g = _group_size(txt)
                type_str = txt.split(token)[0]
                nbytes = _shapes_bytes(type_str)
                if coll == "all-gather":
                    wire = nbytes * (g - 1) / max(g, 1)
                elif coll == "reduce-scatter":
                    wire = nbytes * (g - 1)
                elif coll == "all-reduce":
                    wire = 2 * nbytes * (g - 1) / max(g, 1)
                elif coll == "all-to-all":
                    wire = nbytes * (g - 1) / max(g, 1)
                else:
                    wire = nbytes
                s.collective_bytes[coll] = s.collective_bytes.get(coll, 0.0) + m0 * wire
                s.collective_counts[coll] = s.collective_counts.get(coll, 0) + 1
                s.per_collective.append(
                    {"comp": cname, "op": coll, "bytes": nbytes, "group": g,
                     "mult": m0, "wire_bytes": m0 * wire})
                break
    return s
