"""Parameter counting (total and active) per architecture config.

Analytic — no tensor allocation; validated against jax.eval_shape trees in
tests/test_roofline.py.
"""
from __future__ import annotations

from repro.models.common import ModelConfig


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    if cfg.kv_lora_rank:    # MLA
        nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                                cfg.v_head_dim, cfg.kv_lora_rank)
        return (d * cfg.n_heads * (nope + rope) + d * (lora + rope)
                + lora * cfg.n_heads * (nope + vd) + cfg.n_heads * vd * d)
    return (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * d)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.act == "swiglu" else 2
    return mult * cfg.d_model * d_ff


def _mamba_params(cfg: ModelConfig) -> int:
    d, di = cfg.d_model, cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    return (2 * d * di              # w_z, w_x
            + 2 * d * gn            # w_B, w_C
            + d * cfg.ssm_heads     # w_dt
            + di * d)               # w_out


def _moe_ffn_params(cfg: ModelConfig, active: bool) -> int:
    d, f, E, k = cfg.d_model, cfg.d_ff_expert, cfg.n_experts, cfg.top_k
    routed = 3 * d * f * (k if active else E)
    shared = 3 * d * (cfg.n_shared_experts * f) if cfg.n_shared_experts else 0
    router = d * E
    return routed + shared + router


def param_count(cfg: ModelConfig, active: bool = False) -> int:
    d, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    embed = V * d
    head = 0 if cfg.tie_embeddings else d * V
    if cfg.family in ("dense", "vlm"):
        per_layer = _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        return embed + head + L * per_layer
    if cfg.family == "moe":
        moe_layers = L - cfg.n_dense_layers
        per_moe = _attn_params(cfg) + _moe_ffn_params(cfg, active)
        per_dense = _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff_dense or cfg.d_ff)
        return embed + head + moe_layers * per_moe + cfg.n_dense_layers * per_dense
    if cfg.family == "ssm":
        return embed + d * V + L * _mamba_params(cfg)
    if cfg.family == "hybrid":
        shared = (2 * d * d + _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff))
        return embed + d * V + L * _mamba_params(cfg) + shared
    if cfg.family == "encdec":
        enc = cfg.enc_layers * (_attn_params(cfg) + _ffn_params(cfg, cfg.d_ff))
        dec = cfg.dec_layers * (2 * _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff))
        return embed + enc + dec
    raise ValueError(cfg.family)


def active_param_count(cfg: ModelConfig) -> int:
    return param_count(cfg, active=True)
