"""Roofline-term assembly from dry-run records (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds per step, per chip):
  compute    = hlo.dot_flops / PEAK_FLOPS
               (dot_flops: trip-count-scaled per-device dot FLOPs from the
               structural HLO parser — cost_analysis counts loop bodies once)
  memory     = bytes_accessed_corrected / HBM_BW
               (cost_analysis 'bytes accessed' scaled by the same loop
               correction ratio observed on FLOPs: bytes distribute like
               flops across the layer scan.  Documented approximation.)
  collective = per-chip wire bytes (ring model, trip-scaled) / ICI_BW

MODEL_FLOPS (the useful-work yardstick):
  train:   6 · N_active · tokens   (fwd 2ND + bwd 4ND)
  prefill: 2 · N_active · tokens
  decode:  2 · N_active · tokens (+ KV-cache read bytes enter the memory
           term, not FLOPs)
divided across 256 chips (the roofline table is single-pod only).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (conservative: 1 active link)
CHIPS_SINGLE_POD = 256

_LEVER = {
    "compute": "raise MXU utilization: cut causal-masking waste (packed "
               "flash), reduce remat recompute, larger µbatch",
    "memory": "cut HBM traffic: fuse/keep weights resident, bf16 grads, "
              "smaller remat window, KV-cache layout",
    "collective": "cut wire bytes: reshard (less FSDP gather), overlap "
                  "collectives with compute, gradient compression, bf16 AR",
}


@dataclass
class RooflineRow:
    arch: str
    shape: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_chip: float
    min_bytes_per_chip: float         # analytic floor: params(+cache+opt) traffic
    hlo_flops_per_chip: float
    temp_gib: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """No-overlap upper bound = sum; perfect overlap = max.  We report
        the bottleneck term as the roofline step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops_per_chip / self.hlo_flops_per_chip
                if self.hlo_flops_per_chip else 0.0)

    @property
    def ideal_step_s(self) -> float:
        """Roofline floor: an ideal implementation is limited by useful
        FLOPs at MXU peak or the unavoidable HBM traffic, whichever larger."""
        return max(self.model_flops_per_chip / PEAK_FLOPS,
                   self.min_bytes_per_chip / HBM_BW)

    @property
    def roofline_fraction(self) -> float:
        """ideal_step / achieved_step — the score we hillclimb."""
        if self.step_s <= 0:
            return 0.0
        return min(self.ideal_step_s / self.step_s, 1.0)

    @property
    def lever(self) -> str:
        return _LEVER[self.bottleneck]


def model_flops_for_cell(arch: str, shape: str) -> float:
    """MODEL_FLOPS per step (global, all chips)."""
    from repro.configs import get_config
    from repro.launch.specs import SHAPES
    from repro.analysis.params import active_param_count

    cfg = get_config(arch)
    kind, S, B = SHAPES[shape]
    n_active = active_param_count(cfg)
    if cfg.family == "encdec":
        tokens = B * (S + max(S // 4, 8)) / 2   # enc+dec, rough half each
    else:
        tokens = B * S
    if kind == "train":
        return 6.0 * n_active * tokens
    if kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * B                    # decode: 1 token per seq


def min_bytes_for_cell(arch: str, shape: str) -> float:
    """Analytic HBM-traffic floor per step (global bytes, all chips).

    train:   params bf16 read (fwd) + read (bwd) + grad fp32 w+r + m,v r+w
             + param write  ≈ N × 26 bytes
    prefill: params bf16 read + KV cache write
    decode:  params(active) bf16 read + full KV/state cache read per token
    """
    from repro.configs import get_config
    from repro.launch.specs import SHAPES
    from repro.analysis.params import param_count, active_param_count

    cfg = get_config(arch)
    kind, S, B = SHAPES[shape]
    n_total = param_count(cfg)
    n_active = active_param_count(cfg)
    cache = cache_bytes(arch, S, B)
    if kind == "train":
        return 26.0 * n_total
    if kind == "prefill":
        return 2.0 * n_total + cache
    return 2.0 * n_active + cache


def cache_bytes(arch: str, S: int, B: int) -> float:
    """Decode-state bytes for one batch (bf16 KV / fp32 SSM states)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "vlm"):
        return 2.0 * cfg.num_layers * B * cfg.n_kv_heads * S * hd * 2
    if cfg.family == "moe":
        if cfg.kv_lora_rank:
            return cfg.num_layers * B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
        w = min(S, cfg.window or S)
        return 2.0 * cfg.num_layers * B * cfg.n_kv_heads * w * hd * 2
    if cfg.family == "ssm":
        return cfg.num_layers * B * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
    if cfg.family == "hybrid":
        ssm = cfg.num_layers * B * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
        napp = -(-cfg.num_layers // max(cfg.shared_attn_period, 1))
        return ssm + 2.0 * napp * B * cfg.n_kv_heads * S * hd * 2
    if cfg.family == "encdec":
        sd = max(S // 4, 8)
        return 2.0 * cfg.dec_layers * B * cfg.n_kv_heads * (sd + S) * hd * 2
    return 0.0


def achieved_bytes_for_cell(arch: str, shape: str, *, grad_accum: int = 1,
                            remat: str = "full", fsdp: bool = True,
                            tp: int = 16, chips: int = CHIPS_SINGLE_POD) -> float:
    """Per-chip HBM traffic of THIS implementation's step structure.

    The CPU-lowered HLO is not a usable proxy for TPU HBM traffic (the CPU
    backend materializes what TPU fusion keeps in VMEM), so the achieved
    memory term is modeled analytically from the step structure the dry-run
    actually compiled — microbatch count, remat policy, FSDP gathers,
    sharding — with documented coefficients:

      weights: FSDP-gathered per layer per µb; full remat re-gathers in bwd
               -> per µb: write+read fwd (2) + regather-write + dgrad/wgrad
               reads (3)  => 5 × W/tp  (no remat: 1 gather, 3 reads => 4)
      acts:    ~K_ACT passes over the [B_µb, S, d] residual stream per layer
               (qkv/o/ffn in+out, norms, + full-remat recompute)
      logits:  fp32 write + softmax read + grad write per µb
      opt:     26 B/param on the local shard (grads fp32 rw, m/v rw, p rw)
      kv:      decode reads the whole local cache per token
    """
    from repro.configs import get_config
    from repro.launch.specs import SHAPES
    from repro.analysis.params import param_count, active_param_count

    cfg = get_config(arch)
    kind, S, B = SHAPES[shape]
    n_total = param_count(cfg)
    W_local = 2.0 * n_total / tp            # bf16 gathered weights per chip
    dp = chips // tp
    K_ACT = 12 if remat == "full" else 8

    if kind == "train":
        M = max(grad_accum, 1)
        Bl = B / dp / M                      # per-chip per-µb batch
        Sd = max(S // 4, 8) if cfg.family == "encdec" else S
        weight_factor = 5.0 if remat == "full" else 4.0
        if not fsdp:
            weight_factor = 3.0              # resident: fwd+dgrad+wgrad reads
        weights = M * weight_factor * W_local
        acts = M * cfg.num_layers * Bl * Sd * cfg.d_model * 2.0 * K_ACT
        logits = M * Bl * Sd * (cfg.vocab_size / tp) * 4.0 * 3.0
        opt = 26.0 * n_total / chips if fsdp else 26.0 * n_total / tp
        return weights + acts + logits + opt
    if kind == "prefill":
        Bl = B / dp
        Sd = max(S // 4, 8) if cfg.family == "encdec" else S
        weights = 2.0 * W_local
        acts = cfg.num_layers * Bl * Sd * cfg.d_model * 2.0 * (K_ACT / 2)
        cache = cache_bytes(arch, S, B) / chips
        return weights + acts + cache
    # decode
    n_active = active_param_count(cfg)
    return 2.0 * n_active / tp + cache_bytes(arch, S, B) / chips


def build_rows(records: List[dict]) -> List[RooflineRow]:
    rows = []
    for r in records:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        hlo = r.get("hlo", {})
        dot = float(hlo.get("dot_flops", 0.0)) + float(hlo.get("conv_flops", 0.0))
        mem_bytes = achieved_bytes_for_cell(
            r["arch"], r["shape"], grad_accum=r.get("grad_accum", 1),
            remat=r.get("remat", "full"), fsdp=r.get("fsdp", True))
        coll = float(hlo.get("total_collective_bytes", 0.0))
        mf = model_flops_for_cell(r["arch"], r["shape"]) / CHIPS_SINGLE_POD
        mb = min_bytes_for_cell(r["arch"], r["shape"]) / CHIPS_SINGLE_POD
        rows.append(RooflineRow(
            arch=r["arch"], shape=r["shape"], kind=r["kind"],
            compute_s=dot / PEAK_FLOPS,
            memory_s=mem_bytes / HBM_BW,
            collective_s=coll / ICI_BW,
            model_flops_per_chip=mf,
            min_bytes_per_chip=mb,
            hlo_flops_per_chip=dot,
            temp_gib=(r["memory"]["temp_size_in_bytes"]
                      + r["memory"]["argument_size_in_bytes"]) / 2**30,
        ))
    return rows


def load_rows(path: str | Path) -> List[RooflineRow]:
    recs = [json.loads(l) for l in Path(path).read_text().splitlines() if l.strip()]
    return build_rows(recs)


def to_markdown(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| useful (6ND/HLO) | roofline frac | mem GiB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        out.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.3e} | {r.memory_s:.3e} "
            f"| {r.collective_s:.3e} | **{r.bottleneck}** | {r.useful_ratio:.2f} "
            f"| {r.roofline_fraction:.1%} | {r.temp_gib:.1f} |\n")
    return "".join(out)
