"""Surrogate-engine benchmark: batched fluid sweeps vs the event oracle.

Runs a fleet-scale sweep grid — the heavy_tail atlas trace over a
200-machine fleet, every surrogate-lowerable policy, many paired seeds —
through the batched fluid engine in ONE ``run_batch`` call, times the
event engine on a sample of the same cells, and records surrogate
cells/sec, event-engine cells/sec and their ratio into the ``surrogate``
section of ``BENCH_sim.json`` (git-commit and engine-id stamped, same
regression-tracking contract as the ``scenarios`` section).

The grid is where the batch engine is structurally strong: the event
engine's cost grows with fleet size (every VM heartbeats through the
whole makespan) while the fluid kernel folds machine capacity into two
scalars, so a fleet-scale what-if sweep is exactly the workload the
surrogate exists for.  The surrogate-side timing is end-to-end — trace
resolution, cell compilation (shared across the grid's policy columns,
as ``run_surrogate`` shares it) and the batched integration — but
excludes one-time XLA compilation, which is reported separately.

Modes:

* default — 1000 cells (5 policies x 200 seeds) in one batched run;
* ``--quick`` — 100 cells (5 policies x 20 seeds) for per-PR regression
  tracking in CI.

Usage::

    PYTHONPATH=src python benchmarks/bench_surrogate.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core.types import ClusterSpec                         # noqa: E402
from repro.experiments.runner import (ExperimentSpec, TraceRef,  # noqa: E402
                                      simulate_cell)
from repro.simcluster.sim import ClusterSim                      # noqa: E402
from repro.simcluster.surrogate import (SURROGATE_ENGINE_ID,     # noqa: E402
                                        build_cell, lower_policy,
                                        run_batch)

EVENT_ENGINE_ID = "simcluster.sim/incremental-index"
POLICIES = ("proposed", "fair", "fifo", "delay", "edf_nopark")
#: cells/sec advantage the batched engine must sustain on this grid
TARGET_RATIO = 50.0


def git_commit() -> str:
    """Short HEAD hash, with ``-dirty`` when the tree has uncommitted
    changes — numbers from uncommitted code must not impersonate a commit."""
    try:
        commit = subprocess.run(
            ["git", "-C", str(REPO_ROOT), "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
            timeout=10).stdout.strip()
        status = subprocess.run(
            ["git", "-C", str(REPO_ROOT), "status", "--porcelain"],
            capture_output=True, text=True, check=True, timeout=10).stdout
        return commit + ("-dirty" if status.strip() else "")
    except Exception:
        return "unknown"


def sweep_spec(n_seeds: int) -> ExperimentSpec:
    return ExperimentSpec(
        name="bench-surrogate-fleet",
        traces=(TraceRef(preset="heavy_tail"),),
        clusters=(ClusterSpec(num_machines=200, vms_per_machine=2,
                              replication=2),),
        schedulers=POLICIES,
        seeds=tuple(range(n_seeds)))


def bench(n_seeds: int, event_sample: int, commit: str) -> dict:
    spec = sweep_spec(n_seeds)
    cells = list(spec.cells())
    print(f"[bench] building {len(cells)} surrogate cells "
          f"({len(POLICIES)} policies x {n_seeds} seeds) ...", flush=True)
    t0 = time.perf_counter()
    resolved: dict = {}
    base: dict = {}
    inputs = []
    for cell in cells:
        tkey = (id(cell.trace), cell.seed)
        if tkey not in resolved:
            resolved[tkey] = cell.trace.resolve(cell.seed)
        trace = resolved[tkey]
        bkey = (id(trace), id(cell.cluster), cell.seed)
        if bkey not in base:
            base[bkey] = build_cell(trace, cell.cluster, cell.scheduler,
                                    cell.seed)
            inputs.append(base[bkey])
        else:
            inputs.append(dataclasses.replace(
                base[bkey], policy=lower_policy(cell.scheduler)))
    t_build = time.perf_counter() - t0
    # one warmup batch triggers XLA compilation for the bucket; the timed
    # run below then measures steady-state sweep throughput (a repeat
    # sweep of a new grid, the common case for atlas exploration)
    print("[bench] compiling kernel (warmup batch) ...", flush=True)
    t0 = time.perf_counter()
    run_batch(inputs[:1])
    t_compile = time.perf_counter() - t0
    print(f"[bench] integrating {len(inputs)} cells in one batched run ...",
          flush=True)
    t0 = time.perf_counter()
    results = run_batch(inputs)
    t_integrate = time.perf_counter() - t0
    finished = sum(r.jobs_finished for r in results)
    t_cell = (t_build + t_integrate) / len(cells)

    print(f"[bench] event engine on {event_sample} sample cells ...",
          flush=True)
    t0 = time.perf_counter()
    for cell in cells[:event_sample]:
        simulate_cell(cell)
    t_event = (time.perf_counter() - t0) / event_sample

    ratio = t_event / t_cell
    return {
        "description": ("heavy_tail trace x 200x2 fleet x "
                        f"{len(POLICIES)} policies x {n_seeds} seeds, "
                        "all cells in one batched run"),
        "surrogate": {
            "engine_id": SURROGATE_ENGINE_ID,
            "git_commit": commit,
            "cells": len(cells),
            "build_time_s": round(t_build, 3),
            "compile_time_s": round(t_compile, 3),
            "integrate_time_s": round(t_integrate, 3),
            "cells_per_sec": round(1.0 / t_cell, 1),
            "jobs_finished": finished,
        },
        "event": {
            "engine_id": EVENT_ENGINE_ID,
            "git_commit": commit,
            "sample_cells": event_sample,
            "wall_time_s_per_cell": round(t_event, 3),
            "cells_per_sec": round(1.0 / t_event, 3),
        },
        "speedup": round(ratio, 1),
        "target_speedup": TARGET_RATIO,
        "meets_target": ratio >= TARGET_RATIO,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="100-cell subset for per-PR regression tracking")
    ap.add_argument("--out", type=Path, default=REPO_ROOT / "BENCH_sim.json")
    args = ap.parse_args(argv)

    commit = git_commit()
    entry = bench(n_seeds=20 if args.quick else 200,
                  event_sample=2 if args.quick else 4, commit=commit)
    entry["mode"] = "quick" if args.quick else "full"

    # merge into BENCH_sim.json without disturbing the event-engine
    # scenario benchmarks that live alongside
    doc_text = args.out.read_text() if args.out.exists() else ""
    doc = json.loads(doc_text) if doc_text.strip() else {}
    doc["surrogate"] = entry
    args.out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"[bench] wrote {args.out}")
    s, e = entry["surrogate"], entry["event"]
    print(f"  surrogate: {s['cells']} cells, {s['cells_per_sec']} cells/s "
          f"(build {s['build_time_s']}s + integrate {s['integrate_time_s']}s"
          f", compile {s['compile_time_s']}s excluded)")
    print(f"  event:     {e['cells_per_sec']} cells/s "
          f"({e['wall_time_s_per_cell']}s/cell over {e['sample_cells']} cells)")
    print(f"  speedup:   {entry['speedup']}x (target {TARGET_RATIO:.0f}x, "
          f"{'MET' if entry['meets_target'] else 'MISSED'})")
    # the target is enforced on the full grid; the quick subset amortizes
    # build cost over 10x fewer cells and is tracked by scripts/check.sh
    # against the committed number instead
    return 0 if (entry["meets_target"] or args.quick) else 1


if __name__ == "__main__":
    raise SystemExit(main())
