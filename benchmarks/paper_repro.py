"""Paper-reproduction benchmarks: Fig. 2, Table 2, Fig. 3, throughput gain.

Each function returns rows (list of dicts) and prints a compact table.
The calibrated paper cluster: 20 machines x 2 VMs, per-VM virtual disks
(replication 1), VM-level placement skew 1.0, 2012 1GbE remote penalty 1.0.
The regime atlas in EXPERIMENTS.md (from `python -m repro.experiments
regimes`) maps how these numbers move across workload regimes and fleet
sizes; the Fig.-2 comparison below runs through the same experiments
warehouse, so its means carry paired-bootstrap 95% CIs.
"""
from __future__ import annotations

import statistics
import tempfile
from typing import Dict, List

from repro.core.baselines import FairScheduler
from repro.core.estimator import min_slots
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler
from repro.experiments.runner import ExperimentSpec, TraceRef, run_experiment
from repro.experiments.stats import (bootstrap_mean_ci,
                                     compare_completion_by_workload)
from repro.simcluster import ClusterSim
from repro.simcluster.workloads import (WORKLOADS, default_deadline,
                                        n_map_tasks, n_reduce_tasks,
                                        paper_cluster, paper_table2_jobs)


def _proposed(spec, max_wait=30.0, park_depth=4):
    s = CompletionTimeScheduler(spec, Reconfigurator(spec, max_wait=max_wait))
    s.park_depth = park_depth
    return s


def fig2_completion_times(seeds=(1, 2, 3), cache_dir=None,
                          n_boot: int = 2000) -> List[Dict]:
    """Fig. 2(a)/(b): per-workload completion times at 2..10 GB under Fair
    vs the proposed scheduler (jobs run as the paper does: the whole mix).

    Runs through the experiments warehouse (``run_experiment`` with a
    ``rows``-kind ``TraceRef``): each seed re-rolls placement + jitter for
    *both* schedulers, and the per-cell gain is a paired bootstrap over
    seeds — the table shows 95% CIs, not bare means.  Pass ``cache_dir`` to
    reuse sweep results across invocations."""
    cluster = paper_cluster()
    tmp = None
    if cache_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro-fig2-")
        cache_dir = tmp.name
    rows: List[Dict] = []
    try:
        for size in (2, 4, 6, 8, 10):
            trace_rows = tuple(
                (w, float(size), default_deadline(w, size), i * 10.0)
                for i, w in enumerate(WORKLOADS))
            spec = ExperimentSpec(
                name=f"fig2-{size}gb",
                traces=(TraceRef(rows=trace_rows, name=f"fig2-{size}gb"),),
                clusters=(cluster,),
                schedulers=("proposed", "fair"),
                seeds=tuple(seeds),
            )
            report = run_experiment(spec, cache_dir)
            by = report.by_scheduler()
            per_w = compare_completion_by_workload(by["fair"], by["proposed"],
                                                   n_boot=n_boot)
            for w, cmp in per_w.items():
                rows.append({
                    "workload": w, "size_gb": size,
                    "fair_s": cmp.mean_a, "proposed_s": cmp.mean_b,
                    "gain_pct": cmp.mean_gain_pct,
                    "ci_lo_pct": cmp.ci_lo_pct, "ci_hi_pct": cmp.ci_hi_pct,
                    "win_rate": cmp.win_rate, "n_pairs": cmp.n_pairs,
                })
    finally:
        if tmp is not None:
            tmp.cleanup()
    print("\n== Fig.2: completion times (s), fair vs proposed "
          f"(paired bootstrap over {len(tuple(seeds))} seeds) ==")
    print(f"{'workload':16s}" + "".join(f"{s}GB".rjust(16)
                                        for s in (2, 4, 6, 8, 10)))
    for w in WORKLOADS:
        cells = []
        for size in (2, 4, 6, 8, 10):
            r = next(r for r in rows
                     if r["workload"] == w and r["size_gb"] == size)
            cells.append(f"{r['fair_s']:6.0f}/{r['proposed_s']:6.0f}")
        print(f"{w:16s}" + "".join(c.rjust(16) for c in cells))
    print("\n   per-cell completion-time gain, 95% CI (warehouse-paired):")
    for w in WORKLOADS:
        cells = []
        for size in (2, 4, 6, 8, 10):
            r = next(r for r in rows
                     if r["workload"] == w and r["size_gb"] == size)
            cells.append(f"{r['gain_pct']:+5.0f}%"
                         f"[{r['ci_lo_pct']:+4.0f},{r['ci_hi_pct']:+4.0f}]")
        print(f"{w:16s}" + "".join(c.rjust(16) for c in cells))
    return rows


def table2_slot_allocation() -> List[Dict]:
    """Table 2: minimum slots via Eq. 10 for the paper's (job, deadline,
    size) rows, with calibrated task-time profiles."""
    rows_in = [("grep", 10, 650.0), ("wordcount", 5, 520.0),
               ("sort", 10, 500.0), ("permutation", 4, 850.0),
               ("inverted_index", 8, 720.0)]
    paper = {"grep": (24, 8), "wordcount": (14, 7), "sort": (20, 11),
             "permutation": (15, 16), "inverted_index": (12, 9)}
    out = []
    print("\n== Table 2: minimum slots to meet deadline (ours vs paper) ==")
    print(f"{'job':16s} {'D(s)':>6s} {'GB':>3s} {'ours n_m/n_r':>14s} {'paper':>9s}")
    for w, gb, dl in rows_in:
        prof = WORKLOADS[w]
        u_m = n_map_tasks(gb)
        v_r = n_reduce_tasks(w, gb)
        d = min_slots(u_m, v_r, prof.map_time, prof.map_time,
                      prof.shuffle_time_per_pair, dl)
        pm, pr = paper[w]
        out.append({"job": w, "deadline": dl, "gb": gb, "n_m": d.n_m,
                    "n_r": d.n_r, "paper_n_m": pm, "paper_n_r": pr,
                    "feasible": d.feasible})
        print(f"{w:16s} {dl:6.0f} {gb:3d} {d.n_m:6d}/{d.n_r:<6d} {pm:4d}/{pr:<4d}")
    return out


def fig3_job_comparison(seeds=(1, 2, 3, 4, 5, 6)) -> List[Dict]:
    """Fig. 3: per-job completion times for the Table-2 mix; the paper's
    observation — permutation generator (reduce-input-heavy) shows ~no
    gain; the others improve."""
    spec = paper_cluster()
    agg = {w: {"fair": [], "proposed": []} for w in WORKLOADS}
    for seed in seeds:
        for name, sched in (("fair", FairScheduler(spec)),
                            ("proposed", _proposed(spec))):
            res = ClusterSim(spec, sched, seed=seed).run(
                paper_table2_jobs(spec, seed=seed))
            for jid, j in res.jobs.items():
                w = jid.rsplit("-", 1)[0]
                agg[w][name].append(res.completion_time(jid))
    rows = []
    print("\n== Fig.3: per-job completion time (s) ==")
    print(f"{'job':16s} {'fair':>8s} {'proposed':>9s} {'gain':>7s}")
    for w, d in agg.items():
        f, p = statistics.mean(d["fair"]), statistics.mean(d["proposed"])
        rows.append({"job": w, "fair_s": f, "proposed_s": p,
                     "gain_pct": (1 - p / f) * 100})
        print(f"{w:16s} {f:8.0f} {p:9.0f} {(1 - p / f) * 100:+6.1f}%")
    return rows


def throughput_gain(seeds=range(1, 13)) -> Dict:
    """§5 headline: job-throughput gain of proposed over Fair (~12%)."""
    spec = paper_cluster()
    gains, locs_f, locs_p, dls = [], [], [], []
    for seed in seeds:
        f = ClusterSim(spec, FairScheduler(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        p = ClusterSim(spec, _proposed(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        gains.append(p.throughput_jobs_per_hour() / f.throughput_jobs_per_hour() - 1)
        locs_f.append(f.locality_rate())
        locs_p.append(p.locality_rate())
        dls.append(p.deadlines_met())
    mean_gain, ci_lo, ci_hi = bootstrap_mean_ci(gains)
    out = {
        "mean_gain_pct": mean_gain * 100,
        "ci_lo_pct": ci_lo * 100,
        "ci_hi_pct": ci_hi * 100,
        "stdev_gain_pct": statistics.stdev(gains) * 100,
        "locality_fair": statistics.mean(locs_f),
        "locality_proposed": statistics.mean(locs_p),
        "deadlines_met_mean": statistics.mean(dls),
        "paper_claim_pct": 12.0,
        "n_seeds": len(list(seeds)),
    }
    print("\n== Throughput gain (proposed vs fair) ==")
    print(f"  mean gain {out['mean_gain_pct']:+.1f}% "
          f"[{out['ci_lo_pct']:+.1f}%, {out['ci_hi_pct']:+.1f}%] 95% CI "
          f"(paper: ~12%)  locality {out['locality_fair']:.0%} -> "
          f"{out['locality_proposed']:.0%}  deadlines {out['deadlines_met_mean']:.1f}/5")
    return out


def locality_stats(seeds=(1, 2, 3)) -> Dict:
    """§4.1 mechanism stats: reconfigurations, parked waits, expiry rate."""
    spec = paper_cluster()
    stats = {"reconfigurations": [], "parked": [], "expired": [], "wait": []}
    for seed in seeds:
        p = ClusterSim(spec, _proposed(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        rs = p.reconfig_stats
        stats["reconfigurations"].append(rs.get("reconfigurations", 0))
        stats["parked"].append(rs.get("parked", 0))
        stats["expired"].append(rs.get("expired", 0))
        if rs.get("reconfigurations"):
            stats["wait"].append(rs["total_wait"] / rs["reconfigurations"])
    out = {k: statistics.mean(v) if v else 0.0 for k, v in stats.items()}
    print("\n== Algorithm-1 mechanism stats ==")
    print(f"  reconfigurations/run {out['reconfigurations']:.0f}, parked "
          f"{out['parked']:.0f}, expired {out['expired']:.0f}, mean wait "
          f"{out['wait']:.1f}s (paper: 'wait time is negligible')")
    return out
