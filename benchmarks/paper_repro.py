"""Paper-reproduction benchmarks: Fig. 2, Table 2, Fig. 3, throughput gain.

Each function returns rows (list of dicts) and prints a compact table.
The calibrated paper cluster: 20 machines x 2 VMs, per-VM virtual disks
(replication 1), VM-level placement skew 1.0, 2012 1GbE remote penalty 1.0
(see EXPERIMENTS.md §Repro for the sensitivity grid over these).
"""
from __future__ import annotations

import statistics
from typing import Dict, List

from repro.core.baselines import FairScheduler
from repro.core.estimator import min_slots
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import CompletionTimeScheduler
from repro.simcluster import ClusterSim
from repro.simcluster.workloads import (WORKLOADS, default_deadline, make_job,
                                        n_map_tasks, n_reduce_tasks,
                                        paper_cluster, paper_table2_jobs,
                                        PAPER_SKEW)
import random


def _proposed(spec, max_wait=30.0, park_depth=4):
    s = CompletionTimeScheduler(spec, Reconfigurator(spec, max_wait=max_wait))
    s.park_depth = park_depth
    return s


def fig2_completion_times(seeds=(1, 2, 3)) -> List[Dict]:
    """Fig. 2(a)/(b): per-workload completion times at 2..10 GB under Fair
    vs the proposed scheduler (jobs run as the paper does: the whole mix)."""
    spec = paper_cluster()
    rows = []
    for size in (2, 4, 6, 8, 10):
        for w in WORKLOADS:
            cts = {"fair": [], "proposed": []}
            for seed in seeds:
                rng = random.Random(seed * 997 + size)
                jobs = [make_job(f"{w2}-{size}", w2, size,
                                 default_deadline(w2, size), spec,
                                 random.Random(seed * 997 + size + i),
                                 submit_time=i * 10.0, skew=PAPER_SKEW)
                        for i, w2 in enumerate(WORKLOADS)]
                for name, sched in (("fair", FairScheduler(spec)),
                                    ("proposed", _proposed(spec))):
                    res = ClusterSim(spec, sched, seed=seed).run(
                        [j for j in jobs])
                    cts[name].append(res.completion_time(f"{w}-{size}"))
                    jobs = [make_job(f"{w2}-{size}", w2, size,
                                     default_deadline(w2, size), spec,
                                     random.Random(seed * 997 + size + i),
                                     submit_time=i * 10.0, skew=PAPER_SKEW)
                            for i, w2 in enumerate(WORKLOADS)]
            rows.append({"workload": w, "size_gb": size,
                         "fair_s": statistics.mean(cts["fair"]),
                         "proposed_s": statistics.mean(cts["proposed"])})
    print("\n== Fig.2: completion times (s), fair vs proposed ==")
    print(f"{'workload':16s}" + "".join(f"{s}GB".rjust(16) for s in (2, 4, 6, 8, 10)))
    for w in WORKLOADS:
        cells = []
        for size in (2, 4, 6, 8, 10):
            r = next(r for r in rows if r["workload"] == w and r["size_gb"] == size)
            cells.append(f"{r['fair_s']:6.0f}/{r['proposed_s']:6.0f}")
        print(f"{w:16s}" + "".join(c.rjust(16) for c in cells))
    return rows


def table2_slot_allocation() -> List[Dict]:
    """Table 2: minimum slots via Eq. 10 for the paper's (job, deadline,
    size) rows, with calibrated task-time profiles."""
    rows_in = [("grep", 10, 650.0), ("wordcount", 5, 520.0),
               ("sort", 10, 500.0), ("permutation", 4, 850.0),
               ("inverted_index", 8, 720.0)]
    paper = {"grep": (24, 8), "wordcount": (14, 7), "sort": (20, 11),
             "permutation": (15, 16), "inverted_index": (12, 9)}
    out = []
    print("\n== Table 2: minimum slots to meet deadline (ours vs paper) ==")
    print(f"{'job':16s} {'D(s)':>6s} {'GB':>3s} {'ours n_m/n_r':>14s} {'paper':>9s}")
    for w, gb, dl in rows_in:
        prof = WORKLOADS[w]
        u_m = n_map_tasks(gb)
        v_r = n_reduce_tasks(w, gb)
        d = min_slots(u_m, v_r, prof.map_time, prof.map_time,
                      prof.shuffle_time_per_pair, dl)
        pm, pr = paper[w]
        out.append({"job": w, "deadline": dl, "gb": gb, "n_m": d.n_m,
                    "n_r": d.n_r, "paper_n_m": pm, "paper_n_r": pr,
                    "feasible": d.feasible})
        print(f"{w:16s} {dl:6.0f} {gb:3d} {d.n_m:6d}/{d.n_r:<6d} {pm:4d}/{pr:<4d}")
    return out


def fig3_job_comparison(seeds=(1, 2, 3, 4, 5, 6)) -> List[Dict]:
    """Fig. 3: per-job completion times for the Table-2 mix; the paper's
    observation — permutation generator (reduce-input-heavy) shows ~no
    gain; the others improve."""
    spec = paper_cluster()
    agg = {w: {"fair": [], "proposed": []} for w in WORKLOADS}
    for seed in seeds:
        for name, sched in (("fair", FairScheduler(spec)),
                            ("proposed", _proposed(spec))):
            res = ClusterSim(spec, sched, seed=seed).run(
                paper_table2_jobs(spec, seed=seed))
            for jid, j in res.jobs.items():
                w = jid.rsplit("-", 1)[0]
                agg[w][name].append(res.completion_time(jid))
    rows = []
    print("\n== Fig.3: per-job completion time (s) ==")
    print(f"{'job':16s} {'fair':>8s} {'proposed':>9s} {'gain':>7s}")
    for w, d in agg.items():
        f, p = statistics.mean(d["fair"]), statistics.mean(d["proposed"])
        rows.append({"job": w, "fair_s": f, "proposed_s": p,
                     "gain_pct": (1 - p / f) * 100})
        print(f"{w:16s} {f:8.0f} {p:9.0f} {(1 - p / f) * 100:+6.1f}%")
    return rows


def throughput_gain(seeds=range(1, 13)) -> Dict:
    """§5 headline: job-throughput gain of proposed over Fair (~12%)."""
    spec = paper_cluster()
    gains, locs_f, locs_p, dls = [], [], [], []
    for seed in seeds:
        f = ClusterSim(spec, FairScheduler(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        p = ClusterSim(spec, _proposed(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        gains.append(p.throughput_jobs_per_hour() / f.throughput_jobs_per_hour() - 1)
        locs_f.append(f.locality_rate())
        locs_p.append(p.locality_rate())
        dls.append(p.deadlines_met())
    out = {
        "mean_gain_pct": statistics.mean(gains) * 100,
        "stdev_gain_pct": statistics.stdev(gains) * 100,
        "locality_fair": statistics.mean(locs_f),
        "locality_proposed": statistics.mean(locs_p),
        "deadlines_met_mean": statistics.mean(dls),
        "paper_claim_pct": 12.0,
        "n_seeds": len(list(seeds)),
    }
    print("\n== Throughput gain (proposed vs fair) ==")
    print(f"  mean gain {out['mean_gain_pct']:+.1f}% ± {out['stdev_gain_pct']:.1f} "
          f"(paper: ~12%)  locality {out['locality_fair']:.0%} -> "
          f"{out['locality_proposed']:.0%}  deadlines {out['deadlines_met_mean']:.1f}/5")
    return out


def locality_stats(seeds=(1, 2, 3)) -> Dict:
    """§4.1 mechanism stats: reconfigurations, parked waits, expiry rate."""
    spec = paper_cluster()
    stats = {"reconfigurations": [], "parked": [], "expired": [], "wait": []}
    for seed in seeds:
        p = ClusterSim(spec, _proposed(spec), seed=seed).run(
            paper_table2_jobs(spec, seed=seed))
        rs = p.reconfig_stats
        stats["reconfigurations"].append(rs.get("reconfigurations", 0))
        stats["parked"].append(rs.get("parked", 0))
        stats["expired"].append(rs.get("expired", 0))
        if rs.get("reconfigurations"):
            stats["wait"].append(rs["total_wait"] / rs["reconfigurations"])
    out = {k: statistics.mean(v) if v else 0.0 for k, v in stats.items()}
    print("\n== Algorithm-1 mechanism stats ==")
    print(f"  reconfigurations/run {out['reconfigurations']:.0f}, parked "
          f"{out['parked']:.0f}, expired {out['expired']:.0f}, mean wait "
          f"{out['wait']:.1f}s (paper: 'wait time is negligible')")
    return out
