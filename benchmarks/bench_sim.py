"""Simulation-engine benchmark: indexed engine vs the frozen seed engine.

Runs large-fleet scenarios from ``repro.simcluster.largescale`` on the
optimized (incremental-index) engine and, where the seed engine can run them
at all, on the frozen legacy engine, and writes ``BENCH_sim.json`` at the
repo root with wall time, events/sec and the speedup ratio per scenario.

Modes:

* default — the full regression benchmark: paper cluster (both engines) +
  the sustained 100-machine / 120-job scenario (both engines, ≥10× target)
  + the larger indexed-only fleets + the fault-injection churn fleet;
* ``--quick`` — < 60 s subset for per-PR regression tracking: paper cluster
  (both engines) + the smoke fleet (both engines) + the sustained and
  churn 100-machine fleets on the indexed engine only.

Usage::

    PYTHONPATH=src python benchmarks/bench_sim.py [--quick] [--out PATH]
    PYTHONPATH=src python benchmarks/bench_sim.py --scenarios fleet_200x4
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

# engine identifiers stamped into every per-engine entry so the perf
# trajectory across PRs stays attributable to a specific implementation
ENGINE_IDS = {
    "indexed": "simcluster.sim/incremental-index",
    "legacy": "simcluster._legacy/seed-frozen",
}


def git_commit() -> str:
    """Short HEAD hash, with ``-dirty`` when the tree has uncommitted
    changes — numbers from uncommitted code must not impersonate a commit."""
    try:
        commit = subprocess.run(
            ["git", "-C", str(REPO_ROOT), "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, check=True,
            timeout=10).stdout.strip()
        status = subprocess.run(
            ["git", "-C", str(REPO_ROOT), "status", "--porcelain"],
            capture_output=True, text=True, check=True, timeout=10).stdout
        return commit + ("-dirty" if status.strip() else "")
    except Exception:
        return "unknown"

from repro.core.reconfigurator import Reconfigurator            # noqa: E402
from repro.core.scheduler import CompletionTimeScheduler        # noqa: E402
from repro.simcluster._legacy import (LegacyClusterSim,         # noqa: E402
                                      LegacyCompletionTimeScheduler,
                                      LegacyReconfigurator)
from repro.simcluster.largescale import SCENARIOS, run_scenario  # noqa: E402
from repro.simcluster.sim import ClusterSim                      # noqa: E402
from repro.simcluster.workloads import (paper_cluster,           # noqa: E402
                                        paper_table2_jobs)


def _summarize(result, wall: float, engine: str, commit: str) -> dict:
    done = sum(1 for j in result.jobs.values() if j.finish_time is not None)
    return {
        "engine_id": ENGINE_IDS[engine],
        "git_commit": commit,
        "wall_time_s": round(wall, 4),
        "events": result.events_processed,
        "events_per_sec": round(result.events_processed / wall, 1) if wall else None,
        "sim_makespan_s": round(result.makespan, 2),
        "jobs_finished": done,
        "jobs_total": len(result.jobs),
        "deadlines_met": result.deadlines_met(),
        "locality_rate": round(result.locality_rate(), 4),
        "speculative_launches": result.speculative_launches,
    }


def bench_paper_cluster(seed: int = 3, commit: str = "unknown") -> dict:
    """Paper-sized cluster on both engines (also a live parity check)."""
    out = {}
    spec = paper_cluster()
    for engine in ("indexed", "legacy"):
        if engine == "indexed":
            sched = CompletionTimeScheduler(spec, Reconfigurator(spec, max_wait=30.0))
            sim = ClusterSim(spec, sched, seed=seed)
        else:
            sched = LegacyCompletionTimeScheduler(
                spec, LegacyReconfigurator(spec, max_wait=30.0))
            sim = LegacyClusterSim(spec, sched, seed=seed)
        t0 = time.perf_counter()
        res = sim.run(paper_table2_jobs(spec, seed=seed))
        out[engine] = _summarize(res, time.perf_counter() - t0, engine, commit)
    out["speedup"] = round(out["legacy"]["wall_time_s"]
                           / out["indexed"]["wall_time_s"], 2)
    out["parity"] = (out["indexed"]["sim_makespan_s"]
                     == out["legacy"]["sim_makespan_s"])
    return out


def bench_scenario(name: str, *, seed: int = 0, engines=("indexed",),
                   commit: str = "unknown", traced: bool = False) -> dict:
    out: dict = {"description": SCENARIOS[name].description}
    for engine in engines:
        t0 = time.perf_counter()
        res = run_scenario(name, engine=engine, seed=seed)
        out[engine] = _summarize(res, time.perf_counter() - t0, engine, commit)
    if traced:
        # same scenario with the decision-trace bus enabled: measures the
        # observer overhead and live-checks the bit-exactness contract.
        # Best-of-3 because single-shot wall clocks on shared machines
        # swing far more than the overhead being measured.
        best_wall, res = None, None
        for _ in range(3):
            t0 = time.perf_counter()
            r = run_scenario(name, engine="indexed", seed=seed, tracing=True)
            wall = time.perf_counter() - t0
            if best_wall is None or wall < best_wall:
                best_wall, res = wall, r
        entry = _summarize(res, best_wall, "indexed", commit)
        entry["engine_id"] += "+trace-bus"
        entry["trace_events"] = res.trace.total
        out["indexed_traced"] = entry
        out["traced_parity"] = (entry["sim_makespan_s"]
                                == out["indexed"]["sim_makespan_s"])
        out["trace_overhead_pct"] = round(
            100.0 * (1.0 - entry["events_per_sec"]
                     / out["indexed"]["events_per_sec"]), 1)
    if "legacy" in out and "indexed" in out:
        out["speedup"] = round(out["legacy"]["wall_time_s"]
                               / out["indexed"]["wall_time_s"], 2)
        out["parity"] = (out["indexed"]["sim_makespan_s"]
                         == out["legacy"]["sim_makespan_s"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="<60s subset for per-PR regression tracking")
    ap.add_argument("--scenarios", nargs="+", default=None,
                    help="explicit scenario names (indexed engine only)")
    ap.add_argument("--traced", action="store_true",
                    help="also run each scenario with the decision-trace "
                         "bus enabled: records indexed_traced events/sec, "
                         "the overhead %% and a traced-parity check")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=Path, default=REPO_ROOT / "BENCH_sim.json")
    args = ap.parse_args(argv)

    commit = git_commit()
    results: dict = {"mode": "quick" if args.quick else "full",
                     "seed": args.seed, "git_commit": commit,
                     "scenarios": {}}
    t_start = time.perf_counter()

    if args.scenarios:
        unknown = [n for n in args.scenarios if n not in SCENARIOS]
        if unknown:
            ap.error(f"unknown scenario(s) {unknown}; "
                     f"available: {', '.join(sorted(SCENARIOS))}")
        for name in args.scenarios:
            print(f"[bench] {name} (indexed"
                  + (" + traced" if args.traced else "") + ") ...",
                  flush=True)
            results["scenarios"][name] = bench_scenario(
                name, seed=args.seed, commit=commit, traced=args.traced)
    else:
        print("[bench] paper cluster (indexed + legacy) ...", flush=True)
        results["scenarios"]["paper_20x2"] = bench_paper_cluster(commit=commit)
        print("[bench] smoke_40x2 (indexed) ...", flush=True)
        results["scenarios"]["smoke_40x2"] = bench_scenario(
            "smoke_40x2", seed=args.seed, commit=commit)
        if args.quick:
            print("[bench] fleet_100x2_sustained (indexed) ...", flush=True)
            results["scenarios"]["fleet_100x2_sustained"] = bench_scenario(
                "fleet_100x2_sustained", seed=args.seed, commit=commit,
                traced=args.traced)
            print("[bench] fleet_100x2_churn (indexed) ...", flush=True)
            results["scenarios"]["fleet_100x2_churn"] = bench_scenario(
                "fleet_100x2_churn", seed=args.seed, commit=commit,
                traced=args.traced)
            print("[bench] fleet_100x2_serving (indexed) ...", flush=True)
            results["scenarios"]["fleet_100x2_serving"] = bench_scenario(
                "fleet_100x2_serving", seed=args.seed, commit=commit,
                traced=args.traced)
        else:
            # the headline comparison: >=100 machines, >=100 jobs, both
            # engines.  The arrival trace is gap-free so the seed engine's
            # heartbeat deadlock does not bias the measurement.
            print("[bench] fleet_100x2_sustained (indexed + legacy, "
                  "the legacy run takes minutes) ...", flush=True)
            results["scenarios"]["fleet_100x2_sustained"] = bench_scenario(
                "fleet_100x2_sustained", seed=args.seed,
                engines=("indexed", "legacy"), commit=commit,
                traced=args.traced)
            for name in ("fleet_100x2", "fleet_200x2", "fleet_200x4",
                         "fleet_400x2", "burst_idle_gap"):
                print(f"[bench] {name} (indexed; impossible on the seed "
                      "engine: idle-gap deadlock / intractable scan cost) ...",
                      flush=True)
                results["scenarios"][name] = bench_scenario(
                    name, seed=args.seed, commit=commit)
            print("[bench] fleet_100x2_churn (indexed; fault injection "
                  "does not exist on the seed engine) ...", flush=True)
            results["scenarios"]["fleet_100x2_churn"] = bench_scenario(
                "fleet_100x2_churn", seed=args.seed, commit=commit)
            print("[bench] fleet_100x2_serving (indexed; the serving layer "
                  "does not exist on the seed engine) ...", flush=True)
            results["scenarios"]["fleet_100x2_serving"] = bench_scenario(
                "fleet_100x2_serving", seed=args.seed, commit=commit)

    results["total_wall_time_s"] = round(time.perf_counter() - t_start, 2)
    if args.out.exists():
        # bench_surrogate.py owns the "surrogate" section of the same
        # file; a scenario re-run must not drop it.  An empty file (e.g.
        # a fresh mktemp target) carries nothing to preserve.
        prior_text = args.out.read_text()
        prior = json.loads(prior_text) if prior_text.strip() else {}
        if "surrogate" in prior:
            results["surrogate"] = prior["surrogate"]
    args.out.write_text(json.dumps(results, indent=2) + "\n")
    print(f"[bench] wrote {args.out}")
    for name, r in results["scenarios"].items():
        line = f"  {name}: "
        if "indexed" in r:
            line += (f"{r['indexed']['wall_time_s']}s, "
                     f"{r['indexed']['events_per_sec']} ev/s")
        if "speedup" in r:
            line += f", speedup {r['speedup']}x, parity={r['parity']}"
        if "indexed_traced" in r:
            line += (f", traced {r['indexed_traced']['events_per_sec']} ev/s "
                     f"({r['trace_overhead_pct']:+.1f}% overhead, "
                     f"traced_parity={r['traced_parity']})")
        print(line)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
