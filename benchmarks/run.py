"""Benchmark harness: one entry per paper table/figure + framework benches.

  python -m benchmarks.run                 # everything (except dry-run)
  python -m benchmarks.run --only fig2     # one artifact
Artifacts: fig2, table2, fig3, throughput, locality, kernels, mapreduce,
roofline (reads benchmarks/results/dryrun_*.jsonl produced by
``python -m repro.launch.dryrun``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).parent / "results"


def bench_paper(only=None):
    from benchmarks import paper_repro as pr
    out = {}
    if only in (None, "fig2"):
        out["fig2"] = pr.fig2_completion_times()
    if only in (None, "table2"):
        out["table2"] = pr.table2_slot_allocation()
    if only in (None, "fig3"):
        out["fig3"] = pr.fig3_job_comparison()
    if only in (None, "throughput"):
        out["throughput"] = pr.throughput_gain()
    if only in (None, "locality"):
        out["locality"] = pr.locality_stats()
    return out


def bench_kernels():
    """Micro-bench the kernels in interpret mode (correctness-path timing;
    TPU wall-time is not measurable on this CPU container)."""
    import jax, jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssd_scan.ops import ssd
    from repro.kernels.ssd_scan.ref import ssd_ref
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 4, 256, 64))
    v = jax.random.normal(ks[2], (1, 4, 256, 64))
    for name, fn in (("flash_attention.interp",
                      lambda: flash_attention(q, k, v, q_block=128,
                                              kv_block=128, interpret=True)),
                     ("attention.ref", lambda: attention_ref(q, k, v))):
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        rows.append({"name": name,
                     "us_per_call": (time.perf_counter() - t0) / 3 * 1e6})
    x = jax.random.normal(ks[0], (1, 256, 4, 16)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4)))
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    B_ = jax.random.normal(ks[1], (1, 256, 1, 8)) * 0.3
    C = jax.random.normal(ks[2], (1, 256, 1, 8)) * 0.3
    for name, fn in (("ssd_scan.interp",
                      lambda: ssd(x, dt, A, B_, C, chunk=64, interpret=True)),
                     ("ssd.ref", lambda: ssd_ref(x, dt, A, B_, C)[0])):
        fn()
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        rows.append({"name": name,
                     "us_per_call": (time.perf_counter() - t0) / 3 * 1e6})
    print("\n== kernel micro-bench (interpret-mode, CPU) ==")
    for r in rows:
        print(f"  {r['name']:28s} {r['us_per_call']:12.0f} us")
    return rows


def bench_mapreduce():
    from repro.mapreduce import MRJob, run_mapreduce
    rows = []
    print("\n== MapReduce engine (jitted, CPU) ==")
    for w in ("wordcount", "grep", "sort", "permutation", "inverted_index"):
        job = MRJob(workload=w, n_blocks=16, block_tokens=8192, n_reducers=8)
        t0 = time.perf_counter()
        out = run_mapreduce(job)
        dt = time.perf_counter() - t0
        rows.append({"workload": w, "ms": dt * 1e3, "checksum": int(out.sum())})
        print(f"  {w:16s} {dt*1e3:8.1f} ms  checksum={int(out.sum())}")
    return rows


def bench_roofline():
    import statistics
    from repro.analysis.roofline import load_rows, to_markdown
    path = RESULTS / "dryrun_baseline.jsonl"
    if not path.exists():
        print("\n== roofline: no dry-run results (run python -m repro.launch.dryrun) ==")
        return []
    rows = load_rows(path)
    (RESULTS / "roofline_baseline.md").write_text(to_markdown(rows))
    print(f"\n== roofline: {len(rows)} single-pod cells "
          f"(tables -> benchmarks/results/roofline_{{baseline,optimized}}.md) ==")
    worst = sorted(rows, key=lambda r: r.roofline_fraction)[:3]
    for r in worst:
        print(f"  baseline worst: {r.arch} {r.shape} "
              f"frac={r.roofline_fraction:.1%} bottleneck={r.bottleneck}")
    out = [{"arch": r.arch, "shape": r.shape, "tag": "baseline",
            "roofline_fraction": r.roofline_fraction,
            "bottleneck": r.bottleneck} for r in rows]
    opath = RESULTS / "dryrun_optimized.jsonl"
    if opath.exists():
        orows = {(r.arch, r.shape): r for r in load_rows(opath)}
        (RESULTS / "roofline_optimized.md").write_text(
            to_markdown(list(orows.values())))
        gains = [(r.arch, r.shape, r.step_s / orows[(r.arch, r.shape)].step_s)
                 for r in rows if (r.arch, r.shape) in orows
                 and orows[(r.arch, r.shape)].step_s > 0]
        geo = statistics.geometric_mean(g for _, _, g in gains)
        print(f"  optimized vs baseline: geomean step gain {geo:.2f}x "
              f"over {len(gains)} cells; top:")
        for a, s, g in sorted(gains, key=lambda x: -x[2])[:5]:
            print(f"    {g:5.2f}x  {a} {s}")
        out += [{"arch": a, "shape": s, "tag": "gain", "step_gain": g}
                for a, s, g in gains]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "fig2", "table2", "fig3", "throughput",
                             "locality", "kernels", "mapreduce", "roofline"])
    args = ap.parse_args()
    RESULTS.mkdir(exist_ok=True)
    out = {}
    if args.only in (None, "fig2", "table2", "fig3", "throughput", "locality"):
        out.update(bench_paper(args.only))
    if args.only in (None, "kernels"):
        out["kernels"] = bench_kernels()
    if args.only in (None, "mapreduce"):
        out["mapreduce"] = bench_mapreduce()
    if args.only in (None, "roofline"):
        out["roofline"] = bench_roofline()
    with open(RESULTS / "bench_summary.json", "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"\nsummary -> {RESULTS / 'bench_summary.json'}")


if __name__ == "__main__":
    main()
