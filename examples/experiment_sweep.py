"""End-to-end tour of the trace-driven experiment CLI on a small grid.

Drives ``python -m repro.experiments`` exactly as a user would:

1. ``generate`` — synthesize a bursty 20-job trace to JSONL;
2. ``run``      — sweep it over 2 policies x 3 seeds on a 10x2 cluster
                  (6 simulations, cached on disk);
3. ``run`` again — the same grid is served entirely from the cache, and a
                  ``PolicySpec``-style inline policy JSON (the ``delay``
                  baseline with a custom ``locality_delay``) extends the
                  grid, simulating only the new cells;
4. ``compare``  — paired-bootstrap comparison of proposed vs fair;
5. ``policies`` — the registered policy table + smoke run;
6. ``paper --quick`` — the paper's §5 evaluation at reporting depth.

The same grid is expressible in-process with the first-class policy API::

    from repro.core.policies import PolicySpec
    from repro.experiments.runner import ExperimentSpec, TraceRef
    spec = ExperimentSpec(
        name="sweep",
        traces=(TraceRef(path="trace.jsonl"),),
        clusters=(ClusterSpec(num_machines=10, vms_per_machine=2),),
        schedulers=("proposed",                       # preset name
                    PolicySpec("delay", {"locality_delay": 4})),
        seeds=(0, 1, 2))

Everything lands in a temp directory and the whole script stays well under
a minute::

    PYTHONPATH=src python examples/experiment_sweep.py
"""
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def cli(workdir: Path, *args: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.experiments", *args],
        cwd=workdir, env=env, capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise SystemExit(f"CLI failed: {' '.join(args)}")
    return proc.stdout


def main() -> int:
    t0 = time.time()
    with tempfile.TemporaryDirectory(prefix="exp-sweep-") as tmp:
        work = Path(tmp)
        grid = ["--trace", "trace.jsonl", "--seeds", "0:3",
                "--machines", "10", "--vms", "2", "--cache", "cache"]

        print("== 1. generate a bursty trace ==")
        cli(work, "generate", "--preset", "bursty", "--seed", "0",
            "--num-jobs", "20", "--out", "trace.jsonl")

        print("\n== 2. sweep: 2 schedulers x 3 seeds ==")
        out = cli(work, "run", *grid, "--schedulers", "proposed", "fair")
        assert "6 simulated, 0 cached" in out, out

        print("\n== 3. re-run: zero new simulations; an inline policy JSON "
              "extends the grid ==")
        out = cli(work, "run", *grid, "--schedulers", "proposed", "fair")
        assert "0 simulated, 6 cached" in out, out
        out = cli(work, "run", *grid, "--schedulers", "proposed", "fair",
                  "--policy", '{"name": "delay", "params": '
                              '{"locality_delay": 4}}')
        assert "3 simulated, 6 cached" in out, out
        assert "delay[locality_delay=4]" in out, out

        print("\n== 4. paired comparison (reuses the same cache) ==")
        out = cli(work, "compare", *grid, "--a", "fair", "--b", "proposed")
        assert "95% CI" in out, out

        print("\n== 5. the registered policy table + smoke ==")
        out = cli(work, "policies", "--smoke")
        assert "policy smoke passed" in out, out

        print("\n== 6. the paper evaluation, quick preset ==")
        cli(work, "paper", "--quick", "--cache", "paper-cache")

    print(f"\nall done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
