"""Quickstart: train a tiny llama-family model for a few steps on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import DataConfig, ShardedDataset, make_batch_iter
from repro.launch.steps import make_train_step
from repro.models.common import get_model
from repro.optim import AdamWConfig, adamw_init


def main(steps: int = 20) -> None:
    cfg = get_smoke_config("llama3.2-3b").replace(num_layers=4, d_model=256,
                                                  n_heads=8, n_kv_heads=4,
                                                  d_ff=512)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params ({cfg.num_layers}L d={cfg.d_model})")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8,
                      num_shards=16)
    ds = ShardedDataset(data, num_hosts=1)
    batches = make_batch_iter(ds, hosts=[0])

    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=1))

    t0 = time.time()
    for i in range(steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(batches).items()}
        params, opt, metrics = step(params, opt, batch)
        if i % 5 == 0 or i == steps - 1:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")
    dt = time.time() - t0
    toks = steps * data.global_batch * data.seq_len
    print(f"done: {dt:.1f}s  ({toks/dt:.0f} tok/s on CPU)")


if __name__ == "__main__":
    main()
