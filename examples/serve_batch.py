"""Batched serving demo: prefill a batch of prompts, then decode with the
KV cache — the serve-side path that the decode_* dry-run cells lower.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.common import get_model


def main(batch: int = 4, prompt_len: int = 48, gen_tokens: int = 32) -> None:
    cfg = get_smoke_config("tinyllama-1.1b")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    max_len = prompt_len + gen_tokens

    # prefill into a max_len cache: run prefill, then copy into a padded cache
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    pad = max_len - prompt_len
    cache = {
        "k": jnp.pad(cache["k"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "v": jnp.pad(cache["v"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "len": cache["len"],
    }
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(gen_tokens - 1):
        logits, cache = decode(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill: {batch}x{prompt_len} tokens in {t_prefill*1e3:.0f} ms")
    print(f"decode:  {gen_tokens-1} steps in {t_decode*1e3:.0f} ms "
          f"({batch*(gen_tokens-1)/t_decode:.0f} tok/s)")
    print("sample generated ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
