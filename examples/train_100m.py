"""End-to-end training driver: data pipeline -> sharded train loop ->
deadline estimation -> async checkpointing -> restart recovery.

    PYTHONPATH=src python examples/train_100m.py                  # tiny preset (~1 min)
    PYTHONPATH=src python examples/train_100m.py --preset 100m    # ~100M params, 300 steps

The deadline logic is the paper's Eq. 10 applied at the framework layer:
remaining steps x measured step time vs the completion-time goal decides the
minimum chip count (printed each log interval; on a one-device CPU box it
reports what a pod-scale run would allocate).
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data import DataConfig, ShardedDataset, make_batch_iter
from repro.elastic.fleet import EstimatorBridge
from repro.launch.steps import make_train_step
from repro.models.common import get_model
from repro.optim import AdamWConfig, adamw_init

PRESETS = {
    "tiny": dict(layers=4, d_model=256, heads=8, kv=4, d_ff=1024, seq=128,
                 batch=8, steps=60, vocab=2048),
    "100m": dict(layers=12, d_model=768, heads=12, kv=4, d_ff=2048, seq=512,
                 batch=16, steps=300, vocab=32000),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--deadline", type=float, default=3600.0,
                    help="completion-time goal (s) for the Eq.-10 estimator")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = get_smoke_config("llama3.2-3b").replace(
        num_layers=p["layers"], d_model=p["d_model"], n_heads=p["heads"],
        n_kv_heads=p["kv"], d_ff=p["d_ff"], vocab_size=p["vocab"])
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n/1e6:.1f}M params | preset={args.preset} "
          f"steps={p['steps']} seq={p['seq']} batch={p['batch']}")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=p["seq"],
                      global_batch=p["batch"], num_shards=64)
    ds = ShardedDataset(data, num_hosts=1)
    batches = make_batch_iter(ds, hosts=[0])

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=p["steps"])
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=2))

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_100m_")
    ck = AsyncCheckpointer(ckpt_dir)
    start = latest_step(ckpt_dir) or 0
    if start:
        state = restore_checkpoint(ckpt_dir, start, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"restored from checkpoint step {start}")

    t_start = time.time()
    step_times = []
    for i in range(start, p["steps"]):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        step_times.append(time.time() - t0)
        if i % 20 == 0 or i == p["steps"] - 1:
            t_step = sum(step_times[-10:]) / len(step_times[-10:])
            remaining = p["steps"] - i - 1
            time_left = args.deadline - (time.time() - t_start)
            chips = EstimatorBridge.demand(max(remaining, 1), t_step, 1,
                                           time_left, total_chips=256)
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"t_step {t_step*1e3:.0f}ms | Eq.10 min-chips for "
                  f"deadline: {chips}")
        if i and i % 50 == 0:
            ck.save(i, {"params": params, "opt": opt})
    ck.save(p["steps"], {"params": params, "opt": opt})
    ck.wait()
    toks = (p["steps"] - start) * p["batch"] * p["seq"]
    dt = time.time() - t_start
    print(f"done in {dt:.0f}s ({toks/dt:.0f} tok/s) | data locality "
          f"{ds.locality_rate():.0%} | ckpt -> {ckpt_dir}")


if __name__ == "__main__":
    main()
