"""Deadline-driven elastic fleet demo — the paper's scheduler running a
multi-job "pod" (fake CPU devices stand in for chips).

Three tiny training jobs with different deadlines share 8 chips (2 hosts x 4):
  * the Eq.-10 estimator sizes each job's chip demand from measured step
    times and the time left to its deadline;
  * chips move between jobs through the per-host Assign/Release queues
    (Algorithm 1), with checkpoint -> re-jit -> resharded-restore standing in
    for vCPU hot-plug;
  * at --fail-step a host "dies": its chips vanish and the affected job
    recovers from its last checkpoint on the remaining chips.

    PYTHONPATH=src python examples/deadline_fleet.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.data import DataConfig, ShardedDataset, make_batch_iter
from repro.elastic import ChipPool, FleetJob, FleetScheduler
from repro.launch.steps import make_train_step
from repro.models.common import get_model
from repro.optim import AdamWConfig, adamw_init


def make_job_factory(seed: int, steps: int):
    cfg = get_smoke_config("tinyllama-1.1b").replace(
        num_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256)
    model = get_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      num_shards=16, seed=seed)
    ds = ShardedDataset(data, num_hosts=2)
    batches = make_batch_iter(ds, hosts=[seed % 2])
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=steps)

    def make_step(mesh):
        params = model.init(cfg, jax.random.PRNGKey(seed))
        opt = adamw_init(params)
        inner = make_train_step(cfg, opt_cfg, grad_accum=1)
        sharding = NamedSharding(mesh, P())
        ndev = mesh.devices.size
        bshard = NamedSharding(mesh, P("data") if data.global_batch % ndev == 0
                               else P())

        def step(state):
            batch = next(batches)
            b = {k: jax.device_put(jnp.asarray(v), bshard)
                 for k, v in batch.items()}
            p, o, m = jax.jit(inner)(state["params"], state["opt"], b)
            return {"params": p, "opt": o}

        state = {"params": jax.device_put(params, sharding),
                 "opt": jax.device_put(opt, sharding)}
        shardings = jax.tree_util.tree_map(lambda _: sharding, state)
        return step, state, shardings

    return make_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--fail-host", type=int, default=1)
    ap.add_argument("--fail-after", type=float, default=6.0)
    args = ap.parse_args()

    devices = jax.devices()
    pool = ChipPool(devices, chips_per_host=4)
    root = tempfile.mkdtemp(prefix="fleet_")
    fleet = FleetScheduler(pool, root)

    fleet.submit(FleetJob("job-urgent", deadline=150.0, total_steps=args.steps,
                          make_step=make_job_factory(1, args.steps),
                          preferred_hosts=(0,), min_chips=1))
    fleet.submit(FleetJob("job-mid", deadline=300.0, total_steps=args.steps,
                          make_step=make_job_factory(2, args.steps),
                          preferred_hosts=(1,), min_chips=1))
    fleet.submit(FleetJob("job-lazy", deadline=600.0, total_steps=args.steps // 2,
                          make_step=make_job_factory(3, args.steps),
                          preferred_hosts=(1,), min_chips=1))

    t0 = time.monotonic()
    failed = False
    orig_rebalance = fleet.rebalance

    def rebalance_with_failure():
        nonlocal failed
        if not failed and time.monotonic() - t0 > args.fail_after:
            failed = True
            fleet.handle_host_failure(args.fail_host)
        orig_rebalance()

    fleet.rebalance = rebalance_with_failure
    fleet.run(rebalance_every=3, ckpt_every=4, max_ticks=600)

    print("\n== fleet events ==")
    for e in fleet.events:
        print("  ", e)
    print("\n== job summary ==")
    ok = True
    for j in fleet.jobs.values():
        took = (j.finished_at or time.monotonic()) - j.submitted_at
        met = took <= j.deadline
        ok &= j.done
        print(f"  {j.job_id:10s} steps={j.step}/{j.total_steps} "
              f"took={took:5.1f}s deadline={j.deadline:.0f}s "
              f"met={met} resizes={j.resizes}")
    print(f"\nreconfigurations={pool.reconfigurations} dead_hosts={sorted(pool.dead_hosts)}")
    assert ok, "not all jobs finished"
    print("OK")


if __name__ == "__main__":
    main()
